"""Engine equivalence: the superstep loop IS the reference loop.

The contract pinned here is the repo's strongest: for every execution mode
x codec combination, the chunked engine (``run_federated``) reproduces the
preserved pre-engine loop (``run_federated_reference``) *exactly* — final
global model bitwise-equal, CommLog history equal as Python objects
(bytes, local_loss and eval metrics included), and identical
checkpoint-resume behaviour.  K=1 bypasses ``lax.scan`` entirely; K=4
exercises the scan carry (global state + EF tree + mirror threading).

The SHARDED contract is one notch weaker by construction: the
client-parallel ``shard_map`` engine (client axis split over the mesh,
EF table row-sharded by cid) must be allclose to the single-device engine
— aggregation order changes, bits may not — with CommLog byte accounting
identical and metric trajectories equal to float tolerance.  It is pinned
two ways: in-process tests that run whenever the host is a forced
multi-device CPU (CI's forced-4-device job), and a subprocess grid that
forces 2- and 4-device hosts from inside a normal tier-1 run.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import iid_partition
from repro.data.synth import class_images
from repro.engine import chunk_schedule
from repro.fl.server import (_evaluate_eager, evaluate, run_federated,
                             run_federated_reference)
from repro.models.registry import make_bundle


_BUNDLE = None


def _bundle():
    global _BUNDLE
    if _BUNDLE is None:
        cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"],
                                  input_shape=(8, 8, 1), conv_channels=(4,),
                                  fc_units=(8,), dropout=0.0)
        _BUNDLE = make_bundle(cfg)
    return _BUNDLE


def _data(seed=3):
    x, y = class_images(12, n_classes=4, shape=(8, 8, 1), seed=0)
    return FederatedDataset(iid_partition(x, y, 4),
                            {"x": x[:16], "y": y[:16]}, seed=seed)


def _assert_same(ref, eng):
    for a, b in zip(jax.tree.leaves(ref.global_state),
                    jax.tree.leaves(eng.global_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref.comm.history == eng.comm.history
    assert ref.comm.bytes_up == eng.comm.bytes_up
    assert ref.comm.bytes_down == eng.comm.bytes_down


FL_CASES = {
    "plain": dict(),
    "topk": dict(uplink_codec="topk", topk_frac=0.1),
    "quant+downtopk": dict(uplink_codec="int8", downlink_codec="topk",
                           topk_frac=0.1),
    "fusion-topk": dict(algorithm="fedfusion", fusion_op="conv",
                        uplink_codec="topk", topk_frac=0.1),
}


_REF_CACHE = {}


def _fl_for(case):
    kw = dict(FL_CASES[case])
    algo = kw.pop("algorithm", "fedavg")
    return FLConfig(algorithm=algo, clients_per_round=2, local_steps=2,
                    local_batch=4, lr=0.05, **kw)


def _reference(bundle, mode, case):
    if (mode, case) not in _REF_CACHE:
        _REF_CACHE[mode, case] = run_federated_reference(
            bundle, _fl_for(case), _data(), rounds=6, seed=1, eval_every=2,
            mode=mode)
    return _REF_CACHE[mode, case]


@pytest.mark.parametrize("mode", ["client_parallel", "client_sequential"])
@pytest.mark.parametrize("case", sorted(FL_CASES))
@pytest.mark.parametrize("chunk", [1, 4])
def test_engine_reproduces_reference(mode, case, chunk):
    """Chunked superstep == seed loop: model bitwise, history exactly."""
    bundle = _bundle()
    ref = _reference(bundle, mode, case)
    eng = run_federated(bundle, _fl_for(case), _data(), rounds=6, seed=1,
                        eval_every=2, mode=mode, superstep_rounds=chunk)
    _assert_same(ref, eng)


def test_engine_eval_every_round_in_scan():
    """eval_every=1 folds evaluation into the scan body; the per-round
    acc/loss trajectory still matches the reference exactly."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=2,
                  local_batch=4, lr=0.05)
    ref = run_federated_reference(bundle, fl, _data(), rounds=5, seed=1,
                                  eval_every=1)
    eng = run_federated(bundle, fl, _data(), rounds=5, seed=1, eval_every=1,
                        superstep_rounds=4)
    _assert_same(ref, eng)
    assert all("acc" in h for h in eng.comm.history)


@pytest.mark.parametrize("codec", ["identity", "topk"])
def test_engine_checkpoint_resume_matches_reference(tmp_path, codec):
    """Interrupt at round 4, resume to 8 — both loops land on the same
    state, and the engine restores the device-side EF tree from ef.npz."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=2,
                  local_batch=4, lr=0.05, uplink_codec=codec, topk_frac=0.1)
    dr = _data()
    run_federated_reference(bundle, fl, dr, rounds=4, seed=1, eval_every=4,
                            checkpoint_dir=str(tmp_path / "ref"),
                            checkpoint_every=2)
    ref = run_federated_reference(bundle, fl, dr, rounds=8, seed=1,
                                  eval_every=4,
                                  checkpoint_dir=str(tmp_path / "ref"),
                                  checkpoint_every=2)
    de = _data()
    run_federated(bundle, fl, de, rounds=4, seed=1, eval_every=4,
                  checkpoint_dir=str(tmp_path / "eng"), checkpoint_every=2,
                  superstep_rounds=3)
    eng = run_federated(bundle, fl, de, rounds=8, seed=1, eval_every=4,
                        checkpoint_dir=str(tmp_path / "eng"),
                        checkpoint_every=2, superstep_rounds=3)
    _assert_same(ref, eng)
    assert ref.comm.rounds == eng.comm.rounds == 4  # only rounds 5..8 ran


def test_engine_callback_gets_per_round_state():
    """A callback forces one-round chunks and sees the same (round,
    metrics) sequence as the reference loop."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=1,
                  local_batch=4, lr=0.05)

    def make_cb(store):
        def cb(r, state, metrics):
            store[r] = dict(metrics)
        return cb

    ref_seen, eng_seen = {}, {}
    run_federated_reference(bundle, fl, _data(), rounds=3, seed=1,
                            eval_every=1, callback=make_cb(ref_seen))
    run_federated(bundle, fl, _data(), rounds=3, seed=1, eval_every=1,
                  callback=make_cb(eng_seen), superstep_rounds=4)
    assert ref_seen == eng_seen
    assert sorted(ref_seen) == [0, 1, 2]


def test_engine_prefetch_off_identical():
    """prefetch=False (synchronous staging) changes nothing numerically."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=1,
                  local_batch=4, lr=0.05)
    a = run_federated(bundle, fl, _data(), rounds=4, seed=1,
                      superstep_rounds=2, prefetch=True)
    b = run_federated(bundle, fl, _data(), rounds=4, seed=1,
                      superstep_rounds=2, prefetch=False)
    _assert_same(a, b)


def test_chunk_schedule_boundaries():
    """Chunks never cross eval or checkpoint boundaries."""
    sched = chunk_schedule(0, 20, 8, eval_every=5, ckpt_every=4)
    assert sched[0] == (0, 4)
    flat = [b for _, b in sched]
    assert all(b % 5 == 0 or b % 4 == 0 or b == 20 for b in flat)
    assert sched[-1][1] == 20
    # contiguous, in order
    assert all(sched[i][1] == sched[i + 1][0] for i in range(len(sched) - 1))
    # per-round mode (callback) degenerates to K=1
    assert chunk_schedule(2, 5, 8, per_round=True) == [(2, 3), (3, 4),
                                                       (4, 5)]
    # eval folded into the scan imposes no boundary
    assert chunk_schedule(0, 16, 8, eval_every=None) == [(0, 8), (8, 16)]


def test_engine_auto_chunk_rounds_identical():
    """superstep_rounds='auto' calibrates K on a cloned rng stream — the
    results must stay bitwise-equal to a fixed-K run and the choice lands
    in ServerResult.stats."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=1,
                  local_batch=4, lr=0.05)
    fixed = run_federated(bundle, fl, _data(), rounds=4, seed=1,
                          eval_every=4, superstep_rounds=4)
    auto = run_federated(bundle, fl, _data(), rounds=4, seed=1,
                         eval_every=4, superstep_rounds="auto")
    _assert_same(fixed, auto)
    assert isinstance(auto.stats["chunk_rounds"], int)
    assert auto.stats["chunk_rounds"] >= 8


def test_engine_eval_overlap_identical():
    """Snapshot-based eval dispatch (overlap_eval) changes scheduling
    only: histories and final models match the non-overlapped run
    bitwise."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=1,
                  local_batch=4, lr=0.05)
    a = run_federated(bundle, fl, _data(), rounds=6, seed=1, eval_every=2,
                      superstep_rounds=2, overlap_eval=True)
    b = run_federated(bundle, fl, _data(), rounds=6, seed=1, eval_every=2,
                      superstep_rounds=2, overlap_eval=False)
    _assert_same(a, b)
    assert a.stats["eval_overlap"] and not b.stats["eval_overlap"]


# ---------------------------------------------------------------------------
# Sharded engine: client-parallel shard_map over a forced host mesh
# ---------------------------------------------------------------------------

SHARDED_CASES = {
    "plain": ("client_parallel", dict()),
    "topk": ("client_parallel", dict(uplink_codec="topk", topk_frac=0.1)),
    "quant+downtopk": ("client_parallel",
                       dict(uplink_codec="int8", downlink_codec="topk",
                            topk_frac=0.1)),
    "fusion-topk": ("client_parallel",
                    dict(algorithm="fedfusion", fusion_op="conv",
                         uplink_codec="topk", topk_frac=0.1)),
    "topk-seq": ("client_sequential",
                 dict(uplink_codec="topk", topk_frac=0.1)),
}


def _sharded_fl(case):
    mode, kw = SHARDED_CASES[case]
    kw = dict(kw)
    algo = kw.pop("algorithm", "fedavg")
    return mode, FLConfig(algorithm=algo, clients_per_round=4,
                          local_steps=2, local_batch=4, lr=0.05, **kw)


def _sharded_data(seed=3):
    x, y = class_images(12, n_classes=4, shape=(8, 8, 1), seed=0)
    return FederatedDataset(iid_partition(x, y, 8),
                            {"x": x[:16], "y": y[:16]}, seed=seed)


def assert_results_close(single, sharded, rtol=2e-5, atol=1e-6):
    """Sharded-vs-single contract: model allclose, byte accounting exact,
    metric trajectory equal to float tolerance."""
    for a, b in zip(jax.tree.leaves(single.global_state),
                    jax.tree.leaves(sharded.global_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol)
    assert len(single.comm.history) == len(sharded.comm.history)
    assert single.comm.bytes_up == sharded.comm.bytes_up
    assert single.comm.bytes_down == sharded.comm.bytes_down
    for h1, h2 in zip(single.comm.history, sharded.comm.history):
        assert set(h1) == set(h2)
        for k in h1:
            if isinstance(h1[k], float):
                np.testing.assert_allclose(h1[k], h2[k], rtol=1e-4,
                                           atol=1e-5)
            else:
                assert h1[k] == h2[k], k


_multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a forced multi-device host "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=N + "
           "REPRO_ALLOW_FORCED_DEVICES=1)")


@_multidevice
@pytest.mark.parametrize("case", sorted(SHARDED_CASES))
def test_sharded_engine_matches_single_device(case):
    from repro.launch.mesh import make_engine_mesh
    mode, fl = _sharded_fl(case)
    bundle = _bundle()
    mesh = make_engine_mesh()   # all forced devices on the data axis
    single = run_federated(bundle, fl, _sharded_data(), rounds=4, seed=1,
                           eval_every=2, mode=mode, superstep_rounds=2)
    sharded = run_federated(bundle, fl, _sharded_data(), rounds=4, seed=1,
                            eval_every=2, mode=mode, superstep_rounds=2,
                            mesh=mesh)
    assert_results_close(single, sharded)
    assert sharded.stats["client_shards"] == jax.device_count()


@_multidevice
def test_sharded_checkpoint_resume_row_sharded_ef(tmp_path):
    """Interrupt + resume with the EF table row-sharded by cid: the saved
    ef.npz assembles the global table, the resume re-shards it, and the
    two-phase run matches the single-device two-phase run."""
    from repro.launch.mesh import make_engine_mesh
    _, fl = _sharded_fl("topk")
    bundle = _bundle()

    def two_phase(mesh, d):
        run_federated(bundle, fl, _sharded_data(), rounds=4, seed=1,
                      eval_every=4, superstep_rounds=3, mesh=mesh,
                      checkpoint_dir=str(d), checkpoint_every=2)
        return run_federated(bundle, fl, _sharded_data(), rounds=8, seed=1,
                             eval_every=4, superstep_rounds=3, mesh=mesh,
                             checkpoint_dir=str(d), checkpoint_every=2)

    single = two_phase(None, tmp_path / "single")
    sharded = two_phase(make_engine_mesh(), tmp_path / "sharded")
    assert_results_close(single, sharded)


_SHARDED_GRID_SCRIPT = textwrap.dedent("""
    import sys
    import jax
    assert jax.device_count() == int(sys.argv[1]), jax.devices()
    from test_engine import (SHARDED_CASES, _assert_same, _bundle,
                             _sharded_data, _sharded_fl,
                             assert_results_close)
    from repro.fl.server import run_federated
    from repro.launch.mesh import make_engine_mesh

    mesh = make_engine_mesh()
    for case in sys.argv[2:]:
        mode, fl = _sharded_fl(case)
        single = run_federated(_bundle(), fl, _sharded_data(), rounds=4,
                               seed=1, eval_every=2, mode=mode,
                               superstep_rounds=2)
        sharded = run_federated(_bundle(), fl, _sharded_data(), rounds=4,
                                seed=1, eval_every=2, mode=mode,
                                superstep_rounds=2, mesh=mesh)
        assert_results_close(single, sharded)
        # the fused one-psum round must match the three-collective
        # oracle BITWISE (models and full histories, eval included)
        unfused = run_federated(_bundle(), fl, _sharded_data(), rounds=4,
                                seed=1, eval_every=2, mode=mode,
                                superstep_rounds=2, mesh=mesh,
                                fused_collective=False)
        _assert_same(unfused, sharded)
        print(f"case {case}: OK")
    print("SHARDED-OK")
""")


@pytest.mark.parametrize("n_devices,cases", [
    (2, ["plain", "topk", "topk-seq"]),
    (4, ["topk", "fusion-topk"]),
])
def test_sharded_equivalence_forced_host_mesh(n_devices, cases):
    """The tier-1-runnable form of the sharded grid: a subprocess forces an
    N-device CPU host (the flag must be set before jax initializes, hence
    the subprocess) and checks sharded == single-device per case."""
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src")
    env = dict(os.environ)
    # drop any inherited force flag (e.g. from CI's forced-4-device job)
    # so the child sees exactly n_devices
    kept = [t for t in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in t]
    env["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={n_devices}"])
    env["REPRO_ALLOW_FORCED_DEVICES"] = "1"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here] + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_GRID_SCRIPT, str(n_devices)] + cases,
        capture_output=True, text=True, env=env, timeout=1200)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED-OK" in out.stdout


def _forced_host_env(n_devices):
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src")
    env = dict(os.environ)
    kept = [t for t in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in t]
    env["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={n_devices}"])
    env["REPRO_ALLOW_FORCED_DEVICES"] = "1"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here] + env.get("PYTHONPATH", "").split(os.pathsep))
    return env


@_multidevice
@pytest.mark.parametrize("case", ["topk", "fusion-topk", "topk-seq"])
def test_sharded_fused_collective_bitwise(case):
    """Acceptance: the fused one-psum round == the three-collective
    oracle BITWISE — final model and full CommLog history (bytes,
    local_loss, eval metrics) — packing psum operands into one buffer is
    a latency change, never a numerics change."""
    from repro.launch.mesh import make_engine_mesh
    mode, fl = _sharded_fl(case)
    bundle = _bundle()
    mesh = make_engine_mesh()
    kw = dict(rounds=4, seed=1, eval_every=2, mode=mode,
              superstep_rounds=2, mesh=mesh)
    fused = run_federated(bundle, fl, _sharded_data(), fused_collective=True,
                          **kw)
    unfused = run_federated(bundle, fl, _sharded_data(),
                            fused_collective=False, **kw)
    _assert_same(unfused, fused)
    assert fused.stats["fused_collective"]
    assert not unfused.stats["fused_collective"]


@_multidevice
def test_sharded_eval_matches_replicated_eval():
    """Sharded evaluation (batch split + masked-sum psum) vs the
    replicated evaluator on the same mesh: training is untouched (models
    bitwise-equal) and the eval metrics agree to float tolerance (the
    split only reassociates the masked sums)."""
    from repro.launch.mesh import make_engine_mesh
    mode, fl = _sharded_fl("topk")
    bundle = _bundle()
    mesh = make_engine_mesh()
    kw = dict(rounds=4, seed=1, eval_every=1, mode=mode,
              superstep_rounds=2, mesh=mesh)
    shd = run_federated(bundle, fl, _sharded_data(), sharded_eval=True, **kw)
    repl = run_federated(bundle, fl, _sharded_data(), sharded_eval=False,
                         **kw)
    for a, b in zip(jax.tree.leaves(repl.global_state),
                    jax.tree.leaves(shd.global_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert_results_close(repl, shd)
    assert shd.stats["sharded_eval"] and not repl.stats["sharded_eval"]


@_multidevice
@pytest.mark.parametrize("resume_on_mesh", [False, True])
def test_sharded_checkpoint_cross_layout_resume(tmp_path, resume_on_mesh):
    """Resident-scratch-row round trip across layouts: a checkpoint saved
    from the sharded [N_loc+1] table restores into BOTH the compact
    single-device layout and the resident sharded layout (ef.npz stays
    format-compatible), and the resumed two-phase run matches the
    single-device two-phase oracle."""
    from repro.launch.mesh import make_engine_mesh
    _, fl = _sharded_fl("topk")
    bundle = _bundle()
    mesh = make_engine_mesh()
    d = tmp_path / "ckpt"
    kw = dict(seed=1, eval_every=4, superstep_rounds=3,
              checkpoint_dir=str(d), checkpoint_every=2)
    # phase 1 on the mesh -> ef.npz written from the resident layout
    run_federated(bundle, fl, _sharded_data(), rounds=4, mesh=mesh, **kw)
    # phase 2 restores into the other (or same) layout
    two_phase = run_federated(bundle, fl, _sharded_data(), rounds=8,
                              mesh=mesh if resume_on_mesh else None, **kw)
    oracle = run_federated(bundle, fl, _sharded_data(), rounds=4, seed=1,
                           eval_every=4, superstep_rounds=3,
                           checkpoint_dir=str(tmp_path / "o"),
                           checkpoint_every=2)
    oracle = run_federated(bundle, fl, _sharded_data(), rounds=8, seed=1,
                           eval_every=4, superstep_rounds=3,
                           checkpoint_dir=str(tmp_path / "o"),
                           checkpoint_every=2)
    assert_results_close(oracle, two_phase)


_ONE_PSUM_SCRIPT = textwrap.dedent("""
    import sys
    import jax
    import jax.numpy as jnp
    assert jax.device_count() == 2, jax.devices()
    from test_engine import _bundle, _sharded_fl
    from repro.analysis import count_collectives, round_body
    from repro.compress import make_codec
    from repro.core.rounds import init_global_state
    from repro.engine.sharded import client_sharding, make_sharded_superstep
    from repro.launch.mesh import make_engine_mesh

    mesh = make_engine_mesh()
    shard = client_sharding(mesh)
    mode, fl = _sharded_fl("topk")
    bundle = _bundle()
    uplink = make_codec(fl.uplink_codec, topk_frac=fl.topk_frac)
    downlink = make_codec(fl.downlink_codec)
    state = jax.eval_shape(lambda k: init_global_state(bundle, fl, k),
                           jax.random.PRNGKey(0))
    uplink.bind(state["model"])
    downlink.bind(state["model"])
    K, C, S, B = 4, fl.clients_per_round, fl.local_steps, fl.local_batch
    n_loc = 8 // shard.n_shards
    ef = [jax.ShapeDtypeStruct(
              ((n_loc + 1) * shard.n_shards,) + z.shape, z.dtype)
          for z in jax.eval_shape(uplink.init_state)]
    args = (state, ef, state["model"],
            {"x": jax.ShapeDtypeStruct((K, C, S, B, 8, 8, 1), jnp.float32),
             "y": jax.ShapeDtypeStruct((K, C, S, B), jnp.int32)},
            jax.ShapeDtypeStruct((K, C), jnp.float32),
            jax.ShapeDtypeStruct((K,), jnp.float32),
            jax.ShapeDtypeStruct((K, C), jnp.int32),
            jax.ShapeDtypeStruct((K,), jnp.int32),
            jax.ShapeDtypeStruct((2,), jnp.uint32))

    counts = {}
    for fused in (True, False):
        fn = make_sharded_superstep(bundle, fl, mode, K, mesh,
                                    uplink=uplink, downlink=downlink,
                                    fused_collective=fused)
        jaxpr = jax.make_jaxpr(fn)(*args)
        # repro.analysis.round_body: the outermost (K-round) scan body
        body = round_body(jaxpr)
        counts[fused] = (count_collectives(body), count_collectives(jaxpr))
    per_round, total = counts[True]
    assert per_round == 1, f"fused round body has {per_round} psums"
    # one prologue psum per chunk (round 0's EF gather + weight total)
    assert total == 2, f"fused superstep has {total} psums"
    assert counts[False][0] >= 3, counts  # the three-collective oracle
    print(f"fused: {per_round} psum/round ({total} total); "
          f"unfused round body: {counts[False][0]} psums")
    print("ONE-PSUM-OK")
""")


def test_fused_superstep_one_psum_per_round():
    """Acceptance: with fused_collective=True the compressed sharded
    round executes exactly ONE psum per round — asserted by counting psum
    eqns in the K-round scan body's jaxpr on a forced 2-device host (the
    chunk adds a single prologue psum)."""
    env = _forced_host_env(2)
    out = subprocess.run([sys.executable, "-c", _ONE_PSUM_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ONE-PSUM-OK" in out.stdout


def test_jitted_evaluate_matches_eager():
    """The pad-and-mask jitted evaluator equals the uncompiled original."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg")
    from repro.core.rounds import init_global_state
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    batch = _data().test_batch()
    fast = evaluate(bundle, fl, state, batch)
    slow = _evaluate_eager(bundle, fl, state, batch)
    assert fast.keys() == slow.keys()
    for k in fast:
        np.testing.assert_allclose(fast[k], slow[k], rtol=1e-5, atol=1e-6)


def test_jitted_evaluate_respects_max_examples():
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg")
    from repro.core.rounds import init_global_state
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    batch = _data().test_batch()
    fast = evaluate(bundle, fl, state, batch, max_examples=8)
    slow = _evaluate_eager(bundle, fl, state, batch, max_examples=8)
    for k in fast:
        np.testing.assert_allclose(fast[k], slow[k], rtol=1e-5, atol=1e-6)


def test_pad_eval_batch_empty_raises():
    """Regression: an empty test batch used to produce bucket=1 with an
    all-false mask — metrics silently degenerate instead of erroring."""
    from repro.engine import pad_eval_batch
    empty = {"x": np.zeros((0, 8, 8, 1), np.float32),
             "y": np.zeros((0,), np.int32)}
    with pytest.raises(ValueError, match="0 examples"):
        pad_eval_batch(empty)


def test_pad_eval_batch_shard_divisible():
    """pad_eval_batch(shard=) rounds the bucket up to a multiple of the
    shard count; the extra rows are masked pad."""
    from repro.engine import pad_eval_batch
    batch = {"x": np.ones((5, 8, 8, 1), np.float32),
             "y": np.ones((5,), np.int32)}
    padded, mask = pad_eval_batch(batch, shard=3)
    assert padded["x"].shape[0] % 3 == 0
    assert int(np.sum(np.asarray(mask))) == 5
    # unsharded: unchanged power-of-two bucketing
    padded, mask = pad_eval_batch(batch)
    assert padded["x"].shape[0] == 8


def test_masked_metric_sums_match_means():
    """The psum-able masked sums divide back to the masked means."""
    import jax.numpy as jnp
    from repro.core import (masked_accuracy, masked_accuracy_sum,
                            masked_cross_entropy, masked_cross_entropy_sum)
    key = jax.random.PRNGKey(1)
    logits = jax.random.normal(key, (8, 5))
    labels = jax.random.randint(key, (8,), 0, 5)
    mask = jnp.arange(8) < 6
    c, w = masked_accuracy_sum(logits, labels, mask)
    assert float(w) == 6.0
    np.testing.assert_allclose(float(c) / float(w),
                               float(masked_accuracy(logits, labels, mask)),
                               rtol=1e-6)
    ce, w2 = masked_cross_entropy_sum(logits, labels, mask)
    np.testing.assert_allclose(
        float(ce) / float(w2),
        float(masked_cross_entropy(logits, labels, mask)), rtol=1e-6)


def _pump_comm():
    from repro.fl.comm import CommLog
    return CommLog().bind_sizes({"model": {"w": np.zeros(4, np.float32)}})


def test_metrics_pump_empty_stack():
    """Regression: an empty metrics stack raised bare StopIteration from
    ``next(iter(stack.values()))`` inside the worker drain."""
    from repro.engine import MetricsPump
    comm = _pump_comm()
    pump = MetricsPump(comm, 2)
    pump.submit({}, None)                      # no per-round metrics
    pump.submit({}, {"acc": np.float32(0.5)})  # eval-only chunk
    pump.close()
    assert comm.rounds == 1                    # the eval-only round logged
    assert comm.history[-1]["acc"] == 0.5


def test_metrics_pump_verbose_nonfloat(capsys):
    """Regression: verbose formatting crashed with ``:.4f`` on non-float
    metric values (e.g. a per-class vector)."""
    from repro.engine import MetricsPump
    comm = _pump_comm()
    pump = MetricsPump(comm, 2, verbose=True)
    pump.submit({"local_loss": np.ones((2,), np.float32),
                 "per_class": np.arange(6, dtype=np.int32).reshape(2, 3)},
                None)
    pump.close()
    out = capsys.readouterr().out
    assert comm.rounds == 2
    assert "local_loss=1.0000" in out
    assert "per_class=" in out
    np.testing.assert_array_equal(comm.history[-1]["per_class"], [3, 4, 5])


def test_ef_scratch_row_layout_round_trip():
    """checkpoint.io strip/insert are exact inverses and keep ef.npz in
    the compact [N, ...] layout; scratch rows restore as zeros at the end
    of every shard block."""
    from repro.checkpoint.io import insert_scratch_rows, strip_scratch_rows
    rng = np.random.default_rng(0)
    compact = {"a": rng.normal(size=(8, 5)).astype(np.float32),
               "b": rng.normal(size=(8,)).astype(np.float32)}
    for s in (1, 2, 4):
        resident = insert_scratch_rows(compact, s)
        for k in compact:
            assert resident[k].shape[0] == 8 + s
        back = strip_scratch_rows(resident, s)
        for k in compact:
            np.testing.assert_array_equal(back[k], compact[k])
    blocks = insert_scratch_rows(compact, 4)["a"].reshape(4, 3, 5)
    assert (blocks[:, -1] == 0).all()


def test_masked_metrics_ignore_padding():
    """Masked accuracy/CE on a padded batch == plain metrics unpadded."""
    import jax.numpy as jnp
    from repro.core import (accuracy, cross_entropy, masked_accuracy,
                            masked_cross_entropy)
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (6, 5))
    labels = jax.random.randint(key, (6,), 0, 5)
    pad_logits = jnp.concatenate([logits, 100 * jnp.ones((2, 5))])
    pad_labels = jnp.concatenate([labels, jnp.zeros((2,), labels.dtype)])
    mask = jnp.arange(8) < 6
    np.testing.assert_allclose(
        float(masked_accuracy(pad_logits, pad_labels, mask)),
        float(accuracy(logits, labels)), rtol=1e-6)
    np.testing.assert_allclose(
        float(masked_cross_entropy(pad_logits, pad_labels, mask)),
        float(cross_entropy(logits, labels)), rtol=1e-5)
