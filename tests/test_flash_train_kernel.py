"""Pallas flash-attention TRAINING kernel vs the O(S^2) oracle.

Forward and all three gradients are swept over shapes (GQA ratios,
sliding windows, non-block-aligned lengths) and dtypes in interpret mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.flash_attn import make_flash_attention
from repro.models.attention import reference_attention


def _mk(key, B, S, H, KV, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, S, H, hd), dtype),
            jax.random.normal(ks[1], (B, S, KV, hd), dtype),
            jax.random.normal(ks[2], (B, S, H // (H // KV), hd), dtype))


def _grads(fn, q, k, v):
    return jax.grad(lambda a, b, c: jnp.sum(jnp.sin(fn(a, b, c))),
                    argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("S,H,KV,hd,window,qb,kb", [
    (64, 4, 2, 16, None, 16, 16),
    (64, 4, 1, 16, None, 16, 16),      # MQA
    (96, 6, 6, 8, None, 32, 16),       # MHA, uneven blocks
    (64, 4, 2, 16, 16, 16, 16),        # sliding window
    (50, 2, 2, 8, None, 16, 16),       # non-aligned S (padding path)
    (33, 4, 2, 8, 8, 16, 16),          # non-aligned + window
    (128, 8, 2, 32, None, 128, 128),   # single block
])
def test_flash_train_fwd_and_grads(S, H, KV, hd, window, qb, kb):
    q, k, v = _mk(jax.random.PRNGKey(S + H), 2, S, H, KV, hd)
    flash = make_flash_attention(causal=True, window=window, q_block=qb,
                                 kv_block=kb, interpret=True)
    ref = lambda a, b, c: reference_attention(a, b, c, window=window)
    np.testing.assert_allclose(flash(q, k, v), ref(q, k, v),
                               atol=2e-5, rtol=2e-5)
    for g1, g2, nm in zip(_grads(flash, q, k, v), _grads(ref, q, k, v),
                          "dq dk dv".split()):
        np.testing.assert_allclose(g1, g2, atol=3e-4, rtol=3e-4,
                                   err_msg=nm)


def test_flash_train_bf16_forward():
    q, k, v = _mk(jax.random.PRNGKey(1), 1, 64, 4, 2, 16, jnp.bfloat16)
    flash = make_flash_attention(q_block=32, kv_block=32, interpret=True)
    got = np.asarray(flash(q, k, v), np.float32)
    want = np.asarray(reference_attention(q, k, v), np.float32)
    np.testing.assert_allclose(got, want, atol=3e-2, rtol=3e-2)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(8, 72), KV=st.sampled_from([1, 2]),
       rep=st.sampled_from([1, 2, 3]),
       window=st.sampled_from([None, 8]))
def test_flash_train_property_sweep(S, KV, rep, window):
    H, hd = KV * rep, 8
    q, k, v = _mk(jax.random.PRNGKey(S * KV * rep), 1, S, H, KV, hd)
    flash = make_flash_attention(causal=True, window=window, q_block=16,
                                 kv_block=16, interpret=True)
    ref = lambda a, b, c: reference_attention(a, b, c, window=window)
    np.testing.assert_allclose(flash(q, k, v), ref(q, k, v),
                               atol=1e-4, rtol=1e-4)
    g1 = _grads(flash, q, k, v)
    g2 = _grads(ref, q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4)


def test_flash_train_value_and_grad_through_layer():
    """The kernel composes under jit + a surrounding linear layer."""
    B, S, H, KV, hd, d = 1, 32, 4, 2, 8, 32
    flash = make_flash_attention(q_block=16, kv_block=16, interpret=True)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (d, H * hd)) / np.sqrt(d)
    x = jax.random.normal(key, (B, S, d))

    @jax.jit
    def loss(w):
        qkv = (x @ w).reshape(B, S, H, hd)
        kk = qkv[:, :, :KV]
        o = flash(qkv, kk, kk)
        return jnp.mean(o ** 2)

    val, grad = jax.value_and_grad(loss)(w)
    assert np.isfinite(float(val))
    assert bool(jnp.all(jnp.isfinite(grad)))
    assert float(jnp.abs(grad).max()) > 0
