"""Optimizers, schedules, checkpointing, comm accounting, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import (load_tree, restore_server_state, save_tree,
                                 save_server_state)
from repro.configs import ARCH_CONFIGS
from repro.fl.comm import CommLog, tree_bytes
from repro.launch.mesh import make_host_mesh
from repro.launch import sharding as sh
from repro.models import transformer as tfm
from repro.optim import exp_decay_per_round, make_optimizer


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,momentum", [("sgd", 0.0), ("sgd", 0.9),
                                           ("adam", 0.0)])
def test_optimizer_converges_on_quadratic(kind, momentum):
    opt_init, opt_update = make_optimizer(kind, momentum)
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt_init(params)
    lr = 0.1 if kind == "sgd" else 0.3
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * (p - target), params)
        params, state = opt_update(params, grads, state, lr)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_sgd_momentum_differs_from_plain():
    init0, up0 = make_optimizer("sgd", 0.0)
    init9, up9 = make_optimizer("sgd", 0.9)
    p = {"w": jnp.ones(2)}
    g = {"w": jnp.ones(2)}
    a, _ = up0(p, g, init0(p), 0.1)
    s9 = init9(p)
    b, s9 = up9(p, g, s9, 0.1)
    np.testing.assert_allclose(a["w"], b["w"])  # first step identical
    a2, _ = up0(a, g, init0(a), 0.1)
    b2, _ = up9(b, g, s9, 0.1)
    assert float(jnp.abs(a2["w"] - b2["w"]).max()) > 1e-6  # then diverge


def test_exp_decay_schedule():
    lr = exp_decay_per_round(2e-3, 0.985)
    np.testing.assert_allclose(float(lr(0)), 2e-3, rtol=1e-6)
    np.testing.assert_allclose(float(lr(10)), 2e-3 * 0.985 ** 10, rtol=1e-5)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_tree_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "nested": {"b": jnp.ones(4), "t": (jnp.zeros(2), jnp.ones(1))}}
    p = str(tmp_path / "t.npz")
    save_tree(p, tree)
    back = load_tree(p, tree)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y), tree, back)


def test_server_state_roundtrip(tmp_path):
    state = {"model": {"w": jnp.ones((3, 3))},
             "fusion": {"lam": jnp.full((4,), 0.5)}}
    d = str(tmp_path / "ckpt")
    save_server_state(d, state, round_idx=17, extra={"lr": 1e-3})
    back, r = restore_server_state(d, state)
    assert r == 17
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y), state, back)


# ---------------------------------------------------------------------------
# Communication accounting
# ---------------------------------------------------------------------------

def test_tree_bytes():
    t = {"a": jnp.zeros((10, 10), jnp.float32), "b": jnp.zeros(5, jnp.int32)}
    assert tree_bytes(t) == 400 + 20


def test_commlog_counts_fusion_upload_overhead():
    state = {"model": {"w": jnp.zeros((100,), jnp.float32)}}
    state_f = dict(state, fusion={"w": jnp.zeros((10,), jnp.float32)})
    a, b = CommLog(), CommLog()
    a.log_round(state, n_clients=4, metrics={})
    b.log_round(state_f, n_clients=4, metrics={})
    assert a.bytes_down == a.bytes_up == 4 * 400
    # fusion module rides along uncompressed in both directions: clients
    # receive the aggregated module and return their trained copy
    assert b.bytes_up == a.bytes_up + 4 * 40
    assert b.bytes_down == a.bytes_down + 4 * 40


def test_commlog_rounds_to_milestone():
    log = CommLog()
    state = {"model": {"w": jnp.zeros(1)}}
    for acc in (0.3, 0.5, 0.93, 0.96):
        log.log_round(state, 1, {"acc": acc})
    assert log.rounds_to("acc", 0.94) == 4
    assert log.rounds_to("acc", 0.5) == 2
    assert log.rounds_to("acc", 0.99) == -1


def test_commlog_log_round_without_bind_sizes_raises():
    """Regression: deferred logging (global_state=None) before bind_sizes
    must raise a real RuntimeError, not a strippable assert."""
    with pytest.raises(RuntimeError, match="bind_sizes"):
        CommLog().log_round(None, 4, {})


def test_commlog_size_fields_are_honest_optionals():
    """Regression: the cached wire sizes default to None, so their
    annotations must be Optional[int] — ``int = None`` breaks typed
    dataclass introspection (get_type_hints-based tooling)."""
    import typing
    hints = typing.get_type_hints(CommLog)
    assert hints["_model_b"] == typing.Optional[int]
    assert hints["_fusion_b"] == typing.Optional[int]
    log = CommLog()
    assert log._model_b is None and log._fusion_b is None
    state = {"model": {"w": jnp.zeros(3)}}
    assert isinstance(log.bind_sizes(state)._model_b, int)
    log.log_round(None, 2, {"acc": 0.5})      # bound -> logs fine
    assert log.history[-1]["acc"] == 0.5


# ---------------------------------------------------------------------------
# Sharding rules (structure-level; the 256/512-device check is the dry-run)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ARCH_CONFIGS))
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_pspecs_rank_matches(name, fsdp):
    """Every param leaf gets a PartitionSpec of matching rank, and sharded
    dims exist — on any mesh (host mesh here; sizes 1 so everything fits)."""
    cfg = ARCH_CONFIGS[name].reduced()
    mesh = make_host_mesh()
    struct = jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    shardings = sh.param_shardings(mesh, struct, fsdp=fsdp)

    def check(leaf, s):
        assert len(s.spec) <= leaf.ndim, (leaf.shape, s.spec)

    jax.tree.map(check, struct, shardings)


def test_shard_if_divisibility():
    mesh = make_host_mesh()  # sizes 1 -> everything "fits"
    assert sh.shard_if(4, mesh, "data") == "data"
    assert sh.shard_if(4, mesh, "nonexistent") is None


def test_cache_shardings_cover_tree():
    cfg = ARCH_CONFIGS["gemma3-1b"].reduced()
    mesh = make_host_mesh()
    struct = jax.eval_shape(lambda: tfm.init_cache(cfg, 4, 64))
    shardings = sh.cache_shardings(mesh, struct)
    assert (jax.tree.structure(shardings, is_leaf=lambda x: hasattr(x, "spec"))
            == jax.tree.structure(struct))


def test_server_checkpoint_resume(tmp_path):
    """run_federated resumes from the last checkpoint: a 4-round run
    interrupted at 2 + resumed equals the checkpointed state at round 4."""
    import dataclasses
    import numpy as np
    from repro.configs import CNN_CONFIGS
    from repro.configs.base import FLConfig
    from repro.data.federated import FederatedDataset
    from repro.data.partition import iid_partition
    from repro.data.synth import class_images
    from repro.fl.server import run_federated
    from repro.models.registry import make_bundle

    cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"], input_shape=(8, 8, 1),
                              conv_channels=(4,), fc_units=(8,), dropout=0.0)
    bundle = make_bundle(cfg)
    x, y = class_images(10, n_classes=4, shape=(8, 8, 1), seed=0)
    data = FederatedDataset(iid_partition(x, y, 2), {"x": x[:8], "y": y[:8]})
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=1,
                  local_batch=4, lr=0.05)
    d = str(tmp_path / "ckpt")

    # run 2 rounds with checkpointing, then resume to 4
    run_federated(bundle, fl, data, rounds=2, checkpoint_dir=d,
                  checkpoint_every=1, eval_every=100)
    res = run_federated(bundle, fl, data, rounds=4, checkpoint_dir=d,
                        checkpoint_every=1, eval_every=100)
    # resumed run only executed rounds 3..4
    assert res.comm.rounds == 2
    # and a fresh directory starts from scratch
    res_fresh = run_federated(bundle, fl, data, rounds=2,
                              checkpoint_dir=str(tmp_path / "fresh"),
                              eval_every=100)
    assert res_fresh.comm.rounds == 2
