"""Pallas kernels (interpret mode) vs the pure-jnp oracles in ref.py.

Each kernel is swept over shapes and dtypes; the Pallas body executes in
Python on CPU (interpret=True) and must match the oracle to tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.decode_attn import flash_decode
from repro.kernels.fusion_conv import fusion_conv
from repro.kernels.mk_mmd import gram_sum

# ---------------------------------------------------------------------------
# MK-MMD gram-sum + mmd2
# ---------------------------------------------------------------------------

WIDTHS = (1.0, 2.0, 4.0, 8.0, 16.0)


def _gram_sum_ref(x, y, sigma, widths):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d2 = (jnp.sum(x * x, -1)[:, None] + jnp.sum(y * y, -1)[None, :]
          - 2.0 * x @ y.T)
    d2 = jnp.maximum(d2, 0.0)
    acc = sum(jnp.exp(-d2 / (2.0 * w * sigma)) for w in widths)
    return jnp.sum(acc) / len(widths)


@pytest.mark.parametrize("n,m,d", [
    (8, 8, 4), (16, 8, 32), (100, 64, 16),      # non-aligned n
    (130, 130, 8),                               # > 1 tile (tile=128)
    (256, 200, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_sum_matches_ref(n, m, d, dtype):
    kx, ky = jax.random.split(jax.random.PRNGKey(n + m))
    x = jax.random.normal(kx, (n, d), dtype)
    y = jax.random.normal(ky, (m, d), dtype)
    sigma = 3.7
    got = gram_sum(x, y, sigma, WIDTHS, interpret=True)
    want = _gram_sum_ref(x, y, sigma, WIDTHS)
    np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16
                               else 1e-5)


@pytest.mark.parametrize("n,m,d", [(16, 16, 8), (64, 32, 32), (130, 70, 16)])
def test_mk_mmd2_pallas_matches_jnp(n, m, d):
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (n, d))
    y = 0.5 * jax.random.normal(ky, (m, d)) + 1.0
    got = ops.mk_mmd2(x, y, WIDTHS, impl="pallas_interpret")
    want = ops.mk_mmd2(x, y, WIDTHS, impl="jnp")
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_mmd_zero_for_identical():
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    v = float(ref.mk_mmd2_ref(x, x, WIDTHS))
    assert abs(v) < 1e-5


def test_mmd_positive_for_shifted():
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y = x + 3.0
    assert float(ref.mk_mmd2_ref(x, y, WIDTHS)) > 0.01


def test_mmd_symmetric():
    kx, ky = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (32, 8))
    y = jax.random.normal(ky, (24, 8)) * 2.0
    a = float(ref.mk_mmd2_ref(x, y, WIDTHS))
    # mk_mmd2 uses sigma from the cross-distances, symmetric in (x, y)
    b = float(ref.mk_mmd2_ref(y, x, WIDTHS))
    np.testing.assert_allclose(a, b, atol=1e-5)  # f32 summation-order noise


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 64), d=st.sampled_from([2, 8, 33]),
       scale=st.floats(0.1, 4.0), shift=st.floats(-2.0, 2.0))
def test_mmd_property_nonneg_and_grows_with_shift(n, d, scale, shift):
    """Biased-estimator MMD^2 >= 0, and distribution shift increases it."""
    x = jax.random.normal(jax.random.PRNGKey(n * d), (n, d)) * scale
    same = float(ref.mk_mmd2_ref(x, x, WIDTHS))
    far = float(ref.mk_mmd2_ref(x, x + shift, WIDTHS))
    assert same >= -1e-6
    assert far >= same - 1e-6


def test_mmd_gradient_flows():
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 8))
    y = x + 1.0
    g = jax.grad(lambda a: ref.mk_mmd2_ref(a, y, WIDTHS))(x)
    assert float(jnp.abs(g).max()) > 0
    assert bool(jnp.all(jnp.isfinite(g)))


def test_mmd_permutation_invariant():
    """MMD is a set statistic: shuffling examples must not change it."""
    x = jax.random.normal(jax.random.PRNGKey(4), (20, 6))
    y = jax.random.normal(jax.random.PRNGKey(5), (20, 6)) + 0.5
    a = float(ref.mk_mmd2_ref(x, y, WIDTHS))
    b = float(ref.mk_mmd2_ref(x[::-1], y, WIDTHS))
    np.testing.assert_allclose(a, b, atol=1e-5)  # f32 summation-order noise


# ---------------------------------------------------------------------------
# FedFusion conv kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,C", [
    ((4, 16), 16),            # [B, C]
    ((2, 7, 32), 32),         # [B, S, C] non-aligned token count
    ((2, 5, 5, 64), 64),      # [B, H, W, C] CNN feature maps
    ((300, 128), 128),        # token axis > tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fusion_conv_matches_ref(shape, C, dtype):
    ks = jax.random.split(jax.random.PRNGKey(shape[0] * C), 3)
    fg = jax.random.normal(ks[0], shape, dtype)
    fl = jax.random.normal(ks[1], shape, dtype)
    w = jax.random.normal(ks[2], (2 * C, C), dtype) / np.sqrt(2 * C)
    got = fusion_conv(fg, fl, w, interpret=True)
    want = ref.fusion_conv_ref(fg, fl, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_fusion_conv_equals_concat_matmul():
    """The kernel's split-W form == literal concat @ W (paper Eq. 6)."""
    C = 24
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    fg = jax.random.normal(ks[0], (10, C))
    fl = jax.random.normal(ks[1], (10, C))
    w = jax.random.normal(ks[2], (2 * C, C))
    want = jnp.concatenate([fg, fl], axis=-1) @ w
    got = ref.fusion_conv_ref(fg, fl, w)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(t=st.integers(1, 80), c=st.sampled_from([4, 16, 40]))
def test_fusion_conv_property_sweep(t, c):
    ks = jax.random.split(jax.random.PRNGKey(t * c), 3)
    fg = jax.random.normal(ks[0], (t, c))
    fl = jax.random.normal(ks[1], (t, c))
    w = jax.random.normal(ks[2], (2 * c, c))
    got = fusion_conv(fg, fl, w, tile_t=16, interpret=True)
    want = ref.fusion_conv_ref(fg, fl, w)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# GQA flash-decode kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,H,KV,hd,valid", [
    (1, 64, 4, 2, 16, 64),
    (2, 128, 8, 1, 32, 100),     # MQA + partial validity
    (2, 100, 4, 4, 16, 77),      # MHA + non-aligned L
    (1, 1024, 8, 2, 64, 1024),   # multi-block cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(B, L, H, KV, hd, valid, dtype):
    ks = jax.random.split(jax.random.PRNGKey(L + valid), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, L, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, L, KV, hd), dtype)
    got = flash_decode(q, k, v, valid, block_l=64, interpret=True)
    want = ref.decode_attn_ref(q, k, v, valid)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(L=st.integers(8, 200), valid=st.integers(1, 200),
       KV=st.sampled_from([1, 2, 4]), rep=st.sampled_from([1, 2, 3]))
def test_flash_decode_property_sweep(L, valid, KV, rep):
    valid = min(valid, L)
    H, hd = KV * rep, 8
    ks = jax.random.split(jax.random.PRNGKey(L * valid), 3)
    q = jax.random.normal(ks[0], (1, 1, H, hd))
    k = jax.random.normal(ks[1], (1, L, KV, hd))
    v = jax.random.normal(ks[2], (1, L, KV, hd))
    got = flash_decode(q, k, v, valid, block_l=32, interpret=True)
    want = ref.decode_attn_ref(q, k, v, valid)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_flash_decode_ignores_invalid_tail():
    """Garbage beyond valid_len must not affect the output."""
    B, L, H, KV, hd, valid = 1, 64, 2, 1, 8, 40
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, L, KV, hd))
    v = jax.random.normal(ks[2], (B, L, KV, hd))
    k_junk = k.at[:, valid:].set(1e4)
    v_junk = v.at[:, valid:].set(-1e4)
    a = flash_decode(q, k, v, valid, block_l=16, interpret=True)
    b = flash_decode(q, k_junk, v_junk, valid, block_l=16, interpret=True)
    np.testing.assert_allclose(a, b, atol=1e-6)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------

def test_ops_auto_resolves_to_jnp_on_cpu():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    got = ops.mk_mmd2(x, x + 1.0, WIDTHS, impl="auto")
    want = ops.mk_mmd2(x, x + 1.0, WIDTHS, impl="jnp")
    np.testing.assert_allclose(got, want)


def test_gqa_flash_decode_wrapper_paths_agree():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 16))
    k = jax.random.normal(ks[1], (2, 96, 2, 16))
    v = jax.random.normal(ks[2], (2, 96, 2, 16))
    a = ops.gqa_flash_decode(q, k, v, 80, impl="jnp")
    b = ops.gqa_flash_decode(q, k, v, 80, impl="pallas_interpret")
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# EF gather / scatter (repro.engine's device-resident error-feedback table)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,n,k", [(6, 256, 3), (5, 100, 5), (16, 384, 4),
                                   (3, 7, 2)])
def test_ef_gather_matches_ref(N, n, k):
    key = jax.random.PRNGKey(N * n)
    table = jax.random.normal(key, (N, n))
    idx = jax.random.permutation(key, N)[:k].astype(jnp.int32)
    want = ops.ef_gather(table, idx, impl="jnp")
    got = ops.ef_gather(table, idx, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("N,n,k", [(6, 256, 3), (5, 100, 5), (16, 384, 4)])
def test_ef_scatter_matches_ref(N, n, k):
    ks = jax.random.split(jax.random.PRNGKey(N + n), 3)
    table = jax.random.normal(ks[0], (N, n))
    idx = jax.random.permutation(ks[1], N)[:k].astype(jnp.int32)
    rows = jax.random.normal(ks[2], (k, n))
    want = ops.ef_scatter(table, idx, rows, impl="jnp")
    got = ops.ef_scatter(table, idx, rows, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # untouched rows preserved, selected rows replaced
    np.testing.assert_array_equal(np.asarray(want[np.asarray(idx)]),
                                  np.asarray(rows))


def test_ef_scatter_gather_roundtrip_multidim():
    """Trailing dims beyond 2-D flatten transparently in the wrappers."""
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    table = jax.random.normal(ks[0], (4, 3, 10))
    rows = jax.random.normal(ks[1], (2, 3, 10))
    idx = jnp.array([2, 0], jnp.int32)
    out = ops.ef_scatter(table, idx, rows, impl="pallas_interpret")
    back = ops.ef_gather(out, idx, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(back), np.asarray(rows))


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_ef_scatter_scratch_row_duplicates(impl):
    """The sharded EF exchange routes not-owned rows to a scratch row
    appended past the table (``repro.engine.superstep.ef_scatter_exchange``):
    duplicate writes may only ever land there, owned rows stay exact and
    the scratch row is discarded.  Pin that contract for both impls."""
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    table = jax.random.normal(ks[0], (5, 40))
    scratch = jnp.concatenate([table, jnp.zeros((1, 40))], axis=0)
    rows = jax.random.normal(ks[1], (4, 40))
    # rows 0 and 2 owned (table rows 3, 1); rows 1, 3 -> scratch row 5
    safe_idx = jnp.array([3, 5, 1, 5], jnp.int32)
    out = ops.ef_scatter(scratch, safe_idx, rows, impl=impl)[:5]
    want = table.at[jnp.array([3, 1])].set(rows[jnp.array([0, 2])])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
