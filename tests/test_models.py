"""Model-layer oracles: MoE, SSD, RG-LRU, RoPE, norms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.layers import layernorm, layernorm_init, rmsnorm, rmsnorm_init
from repro.models.rope import apply_mrope, apply_rope


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,k,act", [(4, 1, "silu"), (4, 2, "silu"),
                                     (8, 2, "gelu"), (4, 4, "silu")])
def test_moe_matches_dense_reference_at_full_capacity(E, k, act):
    d, f = 16, 32
    params = moe_mod.moe_init(jax.random.PRNGKey(0), d, E, f, act)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    got, _ = moe_mod.moe_apply(params, x, top_k=k, act=act,
                               capacity_factor=float(E))  # cap >= T
    want = moe_mod.moe_reference(params, x, top_k=k, act=act)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


def test_moe_dense_residual():
    d, f, E = 8, 16, 4
    params = moe_mod.moe_init(jax.random.PRNGKey(0), d, E, f, "silu",
                              dense_residual=True, d_ff=32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, d))
    got, _ = moe_mod.moe_apply(params, x, top_k=2, act="silu",
                               capacity_factor=float(E), dense_residual=True)
    want = moe_mod.moe_reference(params, x, top_k=2, act="silu",
                                 dense_residual=True)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


def test_moe_capacity_drops_tokens():
    """A tight capacity factor must drop over-capacity tokens (output
    differs from the dense reference) — the documented trade-off."""
    d, f, E = 8, 16, 4
    params = moe_mod.moe_init(jax.random.PRNGKey(0), d, E, f, "silu")
    # craft inputs that all route to the same expert: identical tokens
    x = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(1), (1, 1, d)), (1, 16, d))
    tight, _ = moe_mod.moe_apply(params, x, top_k=1, act="silu",
                                 capacity_factor=0.25)
    full, _ = moe_mod.moe_apply(params, x, top_k=1, act="silu",
                                capacity_factor=float(E))
    assert float(jnp.abs(tight - full).max()) > 1e-6


def test_moe_aux_loss_minimal_when_balanced():
    """Uniform routing gives aux ~= 1 (the Switch lower bound)."""
    d, f, E = 8, 16, 4
    params = moe_mod.moe_init(jax.random.PRNGKey(0), d, E, f, "silu")
    # zero router logits -> uniform gates
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, d))
    _, aux = moe_mod.moe_apply(params, x, top_k=2, act="silu",
                               capacity_factor=float(E))
    assert 0.9 < float(aux) < 1.1


# ---------------------------------------------------------------------------
# SSD (mamba2)
# ---------------------------------------------------------------------------

SSD_KW = dict(expand=2, d_state=8, head_dim=8, conv_width=4)


@pytest.mark.parametrize("S,chunk", [(8, 4), (16, 8), (12, 8), (32, 32)])
def test_ssd_chunked_matches_stepwise(S, chunk):
    d = 16
    params = ssd_mod.ssd_init(jax.random.PRNGKey(0), d, **SSD_KW)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, d))
    got = ssd_mod.ssd_apply(params, x, chunk=chunk, **SSD_KW)
    want = ssd_mod.ssd_reference(params, x, **SSD_KW)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_ssd_decode_continues_sequence():
    """decode(x_t | state after x_{<t}) == seq output at t."""
    d, S = 16, 12
    params = ssd_mod.ssd_init(jax.random.PRNGKey(0), d, **SSD_KW)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, d))
    full = ssd_mod.ssd_reference(params, x, **SSD_KW)
    cache = ssd_mod.ssd_init_cache(1, d, **SSD_KW)
    for t in range(S):
        y, cache = ssd_mod.ssd_decode(params, x[:, t:t + 1], cache, **SSD_KW)
    np.testing.assert_allclose(y, full[:, -1:], atol=1e-5, rtol=1e-4)


def test_ssd_state_decays():
    """With zero input, the carried state must not grow."""
    d = 16
    params = ssd_mod.ssd_init(jax.random.PRNGKey(0), d, **SSD_KW)
    cache = ssd_mod.ssd_init_cache(1, d, **SSD_KW)
    cache = dict(cache, h=jnp.ones_like(cache["h"]))
    x = jnp.zeros((1, 1, d))
    _, new = ssd_mod.ssd_decode(params, x, cache, **SSD_KW)
    assert float(jnp.abs(new["h"]).max()) <= 1.0 + 1e-5


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def test_rglru_assoc_scan_matches_stepwise():
    d, W, S = 12, 16, 20
    params = rglru_mod.rglru_init(jax.random.PRNGKey(0), d, W)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, d))
    got = rglru_mod.rglru_apply(params, x)
    want = rglru_mod.rglru_reference(params, x)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


def test_rglru_decode_continues_sequence():
    d, W, S = 8, 8, 10
    params = rglru_mod.rglru_init(jax.random.PRNGKey(0), d, W)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, d))
    full = rglru_mod.rglru_apply(params, x)
    cache = rglru_mod.rglru_init_cache(1, W)
    for t in range(S):
        y, cache = rglru_mod.rglru_decode(params, x[:, t:t + 1], cache)
    np.testing.assert_allclose(y, full[:, -1:], atol=1e-5, rtol=1e-4)


def test_rglru_stability():
    """|a_t| < 1 by construction: long constant input cannot blow up."""
    d, W = 8, 8
    params = rglru_mod.rglru_init(jax.random.PRNGKey(0), d, W)
    x = jnp.ones((1, 512, d))
    y = rglru_mod.rglru_apply(params, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.abs(y).max()) < 1e3


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def test_rope_preserves_norm():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 1, 16))
    q2, k2 = apply_rope(q, k, jnp.arange(8), theta=1e4, head_dim=16)
    np.testing.assert_allclose(jnp.linalg.norm(q2, axis=-1),
                               jnp.linalg.norm(q, axis=-1), rtol=1e-5)


def test_rope_relative_position_property():
    """<RoPE(q,m), RoPE(k,n)> depends only on m - n."""
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))

    def dot_at(m, n):
        qm, _ = apply_rope(q, q, jnp.array([m]), theta=1e4, head_dim=hd)
        kn, _ = apply_rope(k, k, jnp.array([n]), theta=1e4, head_dim=hd)
        return float(jnp.sum(qm * kn))

    np.testing.assert_allclose(dot_at(3, 1), dot_at(10, 8), rtol=1e-4)
    np.testing.assert_allclose(dot_at(7, 7), dot_at(0, 0), rtol=1e-4)


def test_partial_rotary_leaves_tail_untouched():
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, hd))
    q2, _ = apply_rope(q, q, jnp.arange(4), theta=1e4, head_dim=hd,
                       partial_pct=0.25)
    rot = int(hd * 0.25)
    np.testing.assert_allclose(q2[..., rot:], q[..., rot:])
    assert float(jnp.abs(q2[:, 1:, :, :rot] - q[:, 1:, :, :rot]).max()) > 1e-6


def test_mrope_equals_rope_when_positions_equal():
    """With t==h==w position ids, M-RoPE must equal standard RoPE."""
    hd, S = 16, 6
    sections = (2, 3, 3)
    q = jax.random.normal(jax.random.PRNGKey(0), (1, S, 2, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, S, 1, hd))
    pos = jnp.arange(S)
    pos3 = jnp.broadcast_to(pos, (3, 1, S))
    qa, ka = apply_mrope(q, k, pos3, theta=1e4, head_dim=hd,
                         sections=sections)
    qb, kb = apply_rope(q, k, pos, theta=1e4, head_dim=hd)
    np.testing.assert_allclose(qa, qb, atol=1e-5)
    np.testing.assert_allclose(ka, kb, atol=1e-5)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def test_rmsnorm_unit_scale_output_rms():
    p = rmsnorm_init(32)
    x = 5.0 * jax.random.normal(jax.random.PRNGKey(0), (4, 32))
    y = rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_layernorm_zero_mean_unit_var():
    p = layernorm_init(32)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 3 + 7
    y = layernorm(p, x)
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.var(y, -1), 1.0, atol=1e-3)
