"""shard_map all-to-all MoE dispatch vs the dense reference.

The multi-shard case needs >1 device, so it runs in a subprocess with
forced host devices (the test process itself must keep seeing 1 device —
see conftest.py).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_mesh, mesh_context
from repro.models import moe as moe_mod
from repro.models.moe_dispatch import moe_apply_a2a, set_dispatch_mesh


def test_a2a_matches_reference_single_shard():
    """On a 1x1 mesh the dispatch degenerates to the plain expert FFN."""
    mesh = make_mesh((1, 1), ("data", "model"))
    d, f, E, k = 8, 16, 4, 2
    params = moe_mod.moe_init(jax.random.PRNGKey(0), d, E, f, "silu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, d))
    want = moe_mod.moe_reference(params, x, top_k=k, act="silu")
    set_dispatch_mesh(mesh)
    with mesh_context(mesh):
        got, aux = moe_apply_a2a(params, x, top_k=k, act="silu",
                                 capacity_factor=float(E))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)
    assert np.isfinite(float(aux))


def test_a2a_matches_reference_multi_shard():
    """4 data shards x 1 model shard: full-capacity dispatch == reference."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import moe as moe_mod
        from repro.models.moe_dispatch import moe_apply_a2a, set_dispatch_mesh

        from repro.launch.mesh import make_mesh, mesh_context
        mesh = make_mesh((4,), ("data",))
        d, f, E, k = 16, 32, 8, 2
        params = moe_mod.moe_init(jax.random.PRNGKey(0), d, E, f, "silu")
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, d))
        want = moe_mod.moe_reference(params, x, top_k=k, act="silu")
        set_dispatch_mesh(mesh)
        with mesh_context(mesh):
            got, _ = jax.jit(lambda p, xx: moe_apply_a2a(
                p, xx, top_k=k, act="silu", capacity_factor=float(E)))(
                    params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-4)
        print("MULTI_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "MULTI_OK" in out.stdout, out.stderr[-2000:]


def test_a2a_ep_tp_matches_reference():
    """2 data x 2 model shards: the EP x TP path (psum over model)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import moe as moe_mod
        from repro.models.moe_dispatch import moe_apply_a2a, set_dispatch_mesh

        from repro.launch.mesh import make_mesh, mesh_context
        mesh = make_mesh((2, 2), ("data", "model"))
        d, f, E, k = 16, 32, 4, 2
        params = moe_mod.moe_init(jax.random.PRNGKey(0), d, E, f, "silu")
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 4, d))
        want = moe_mod.moe_reference(params, x, top_k=k, act="silu")
        set_dispatch_mesh(mesh)
        with mesh_context(mesh):
            got, _ = jax.jit(lambda p, xx: moe_apply_a2a(
                p, xx, top_k=k, act="silu", capacity_factor=float(E)))(
                    params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-4)
        print("EPTP_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "EPTP_OK" in out.stdout, out.stderr[-2000:]


def test_a2a_tight_capacity_drops_like_gather_path():
    """With a tight factor the dispatch drops tokens (documented trade-off)
    but stays finite and shaped correctly."""
    mesh = make_mesh((1, 1), ("data", "model"))
    d, f, E, k = 8, 16, 4, 1
    params = moe_mod.moe_init(jax.random.PRNGKey(0), d, E, f, "silu")
    x = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(1), (1, 1, d)), (1, 16, d))
    set_dispatch_mesh(mesh)
    with mesh_context(mesh):
        tight, _ = moe_apply_a2a(params, x, top_k=k, act="silu",
                                 capacity_factor=0.25)
        full, _ = moe_apply_a2a(params, x, top_k=k, act="silu",
                                capacity_factor=float(E))
    assert bool(jnp.all(jnp.isfinite(tight)))
    assert float(jnp.abs(tight - full).max()) > 1e-6
