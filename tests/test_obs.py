"""Observability contracts (repro.obs).

The load-bearing guarantee: telemetry is *bitwise-invisible*.  A
telemetry-on engine run must produce the exact final model, the exact
CommLog byte accounting and the exact non-telemetry metric history of a
telemetry-off run — on a single device for every mode x codec case, and
on a forced multi-device sharded mesh, where the tap sums additionally
must NOT add any collective beyond the PR 5 single fused psum
(jaxpr-asserted).  The rest pins the host-side machinery: RunLog JSONL
round-trip and span nesting, the zero-allocation disabled path, the
MetricsPump exception-abort cleanup, the non-finite metric warning, and
the CommLog record serialization the report CLI consumes.
"""
import json
import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.comm import CommLog
from repro.fl.server import run_federated
from repro.obs import (NULL_RUNLOG, NullRunLog, RunLog, as_runlog,
                       build_report, json_safe, make_telemetry,
                       registered_taps, render)
from repro.obs.telemetry import (ClientTapCtx, TelemetryTap, _TAPS,
                                 register_tap)

from test_engine import FL_CASES, _bundle, _data, _fl_for, _forced_host_env


# ---------------------------------------------------------------------------
# Tentpole: telemetry-on == telemetry-off, bitwise
# ---------------------------------------------------------------------------

def _strip_tele(history):
    return [{k: v for k, v in h.items() if not k.startswith("tele/")}
            for h in history]


def _assert_invisible(off, on):
    """Telemetry-on == telemetry-off: model bitwise, bytes exact, and the
    history identical once the tele/ series are removed."""
    for a, b in zip(jax.tree.leaves(off.global_state),
                    jax.tree.leaves(on.global_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert off.comm.bytes_up == on.comm.bytes_up
    assert off.comm.bytes_down == on.comm.bytes_down
    assert off.comm.history == _strip_tele(on.comm.history)


_TELE_GRID = [("client_parallel", c) for c in sorted(FL_CASES)] \
    + [("client_sequential", "topk")]


@pytest.mark.parametrize("mode,case", _TELE_GRID)
def test_telemetry_bitwise_invisible(mode, case):
    bundle = _bundle()
    kw = dict(rounds=4, seed=1, eval_every=2, mode=mode, superstep_rounds=2)
    off = run_federated(bundle, _fl_for(case), _data(), **kw)
    on = run_federated(bundle, _fl_for(case), _data(), telemetry=True, **kw)
    _assert_invisible(off, on)
    assert on.stats["telemetry"] and not off.stats["telemetry"]
    tele = {k for h in on.comm.history for k in h if k.startswith("tele/")}
    if case == "plain":
        assert {"tele/update_norm", "tele/weight_total"} <= tele
    else:
        assert {"tele/delta_norm_pre", "tele/delta_norm_post",
                "tele/compress_err", "tele/weight_total"} <= tele
    if case in ("topk", "fusion-topk"):    # stateful uplink -> EF taps
        assert {"tele/ef_norm", "tele/ef_delta_ratio"} <= tele


def test_telemetry_tap_subset_and_chunk_invariance():
    """An explicit tap-name list selects only those series, and the tele
    values are chunk-size-invariant like every other engine metric."""
    bundle = _bundle()
    kw = dict(rounds=4, seed=1, eval_every=2)
    a = run_federated(bundle, _fl_for("topk"), _data(), telemetry=("ef",),
                      superstep_rounds=1, **kw)
    b = run_federated(bundle, _fl_for("topk"), _data(), telemetry=("ef",),
                      superstep_rounds=4, **kw)
    tele = {k for h in a.comm.history for k in h if k.startswith("tele/")}
    assert tele == {"tele/ef_norm", "tele/ef_delta_ratio"}
    assert a.comm.history == b.comm.history


_SHARDED_TELE_SCRIPT = textwrap.dedent("""
    import jax
    import numpy as np
    assert jax.device_count() == 2, jax.devices()
    from test_engine import _bundle, _sharded_data, _sharded_fl
    from test_obs import _assert_invisible
    from repro.fl.server import run_federated
    from repro.launch.mesh import make_engine_mesh

    mesh = make_engine_mesh()
    for case in ("plain", "topk", "topk-seq"):
        mode, fl = _sharded_fl(case)
        kw = dict(rounds=4, seed=1, eval_every=2, mode=mode,
                  superstep_rounds=2, mesh=mesh)
        off = run_federated(_bundle(), fl, _sharded_data(), **kw)
        on = run_federated(_bundle(), fl, _sharded_data(), telemetry=True,
                           **kw)
        _assert_invisible(off, on)
        tele = {k for h in on.comm.history for k in h
                if k.startswith("tele/")}
        assert tele, case
        # the per-shard count proves the sums crossed the psum: each of
        # the 2 shards contributed half the round's clients
        assert on.comm.history[0]["tele/clients_per_shard"] \\
            == fl.clients_per_round / 2, on.comm.history[0]
        assert on.comm.history[0]["tele/clients"] == fl.clients_per_round
        print(f"case {case}: OK")
    print("SHARDED-TELE-OK")
""")


def test_sharded_telemetry_bitwise_invisible_forced_host():
    """The sharded form of the tentpole contract, on a forced 2-device
    host: telemetry-on == telemetry-off bitwise under shard_map (fused
    one-psum rounds), with the tap sums provably psum'd across shards."""
    env = _forced_host_env(2)
    out = subprocess.run([sys.executable, "-c", _SHARDED_TELE_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED-TELE-OK" in out.stdout


_TELE_ONE_PSUM_SCRIPT = textwrap.dedent("""
    import jax
    import jax.numpy as jnp
    assert jax.device_count() == 2, jax.devices()
    from test_engine import _bundle, _sharded_fl
    from repro.analysis import count_collectives, round_body
    from repro.compress import make_codec
    from repro.core.rounds import init_global_state
    from repro.engine.sharded import client_sharding, make_sharded_superstep
    from repro.launch.mesh import make_engine_mesh
    from repro.obs.telemetry import make_telemetry

    mesh = make_engine_mesh()
    shard = client_sharding(mesh)
    mode, fl = _sharded_fl("topk")
    bundle = _bundle()
    uplink = make_codec(fl.uplink_codec, topk_frac=fl.topk_frac)
    downlink = make_codec(fl.downlink_codec)
    state = jax.eval_shape(lambda k: init_global_state(bundle, fl, k),
                           jax.random.PRNGKey(0))
    uplink.bind(state["model"])
    downlink.bind(state["model"])
    K, C, S, B = 4, fl.clients_per_round, fl.local_steps, fl.local_batch
    n_loc = 8 // shard.n_shards
    ef = [jax.ShapeDtypeStruct(
              ((n_loc + 1) * shard.n_shards,) + z.shape, z.dtype)
          for z in jax.eval_shape(uplink.init_state)]
    args = (state, ef, state["model"],
            {"x": jax.ShapeDtypeStruct((K, C, S, B, 8, 8, 1), jnp.float32),
             "y": jax.ShapeDtypeStruct((K, C, S, B), jnp.int32)},
            jax.ShapeDtypeStruct((K, C), jnp.float32),
            jax.ShapeDtypeStruct((K,), jnp.float32),
            jax.ShapeDtypeStruct((K, C), jnp.int32),
            jax.ShapeDtypeStruct((K,), jnp.int32),
            jax.ShapeDtypeStruct((2,), jnp.uint32))

    tele = make_telemetry("compressed", n_clients=C,
                          n_shards=shard.n_shards,
                          available=frozenset(("ef",)))
    assert tele is not None and len(tele.taps) >= 3
    fn = make_sharded_superstep(bundle, fl, mode, K, mesh, uplink=uplink,
                                downlink=downlink, fused_collective=True,
                                telemetry=tele)
    jaxpr = jax.make_jaxpr(fn)(*args)
    body = round_body(jaxpr)
    per_round, total = count_collectives(body), count_collectives(jaxpr)
    assert per_round == 1, f"telemetry round body has {per_round} psums"
    assert total == 2, f"telemetry superstep has {total} psums"
    print(f"telemetry-on fused: {per_round} psum/round ({total} total)")
    print("TELE-ONE-PSUM-OK")
""")


def test_sharded_telemetry_adds_no_collective():
    """Acceptance: with every compressed tap active, the fused sharded
    round STILL executes exactly one psum per round (the tap sums ride
    the PR 5 packed collective) — same jaxpr counting as the PR 5 test."""
    env = _forced_host_env(2)
    out = subprocess.run([sys.executable, "-c", _TELE_ONE_PSUM_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "TELE-ONE-PSUM-OK" in out.stdout


# ---------------------------------------------------------------------------
# Tap registry
# ---------------------------------------------------------------------------

def test_make_telemetry_selection():
    t = make_telemetry("plain", n_clients=4)
    assert {tap.name for tap in t.taps} == {"update", "weights"}
    t = make_telemetry("compressed", n_clients=4)
    assert {tap.name for tap in t.taps} == {"delta", "weights"}
    t = make_telemetry("compressed", n_clients=4,
                       available=frozenset(("ef",)))
    assert {tap.name for tap in t.taps} == {"delta", "ef", "weights"}
    assert t.round_ctx.n_clients == 4
    # nothing applies -> None (treated as telemetry-off)
    assert make_telemetry("plain", taps=("ef",)) is None
    with pytest.raises(KeyError):
        make_telemetry("plain", taps=("nonsense",))


def test_register_tap_plugin():
    class LossTap(TelemetryTap):
        name = "losscheck"
        kinds = ("plain", "compressed")
        requires = ("loss",)

        def client_sums(self, ctx):
            return {"sum": jnp.asarray(ctx.loss, jnp.float32)}

        def finish(self, summed, ctx):
            return {"loss_mean": summed["losscheck.sum"] / ctx.n_clients}

    register_tap(LossTap())
    try:
        assert "losscheck" in registered_taps()
        t = make_telemetry("plain", n_clients=2, taps=("losscheck",))
        sums = t.client_sums(ClientTapCtx(loss=jnp.float32(3.0)))
        assert set(sums) == {"losscheck.sum"}
        out = t.finish({"losscheck.sum": jnp.float32(6.0)})
        assert float(out["tele/loss_mean"]) == 3.0
    finally:
        _TAPS.pop("losscheck", None)
    with pytest.raises(ValueError):
        register_tap(TelemetryTap())    # default name rejected


def test_registered_taps_ride_engine(tmp_path):
    """A registered plugin tap's series shows up in the engine history."""
    class NexTap(TelemetryTap):
        name = "nexmax"
        kinds = ("plain",)
        requires = ("n_examples",)

        def client_sums(self, ctx):
            return {"sum": jnp.asarray(ctx.n_examples, jnp.float32)}

        def finish(self, summed, ctx):
            return {"nex_sum": summed["nexmax.sum"]}

    register_tap(NexTap())
    try:
        res = run_federated(_bundle(), _fl_for("plain"), _data(), rounds=2,
                            seed=1, eval_every=2, superstep_rounds=2,
                            telemetry=("nexmax",))
        assert all("tele/nex_sum" in h for h in res.comm.history)
    finally:
        _TAPS.pop("nexmax", None)


# ---------------------------------------------------------------------------
# RunLog
# ---------------------------------------------------------------------------

def test_runlog_jsonl_roundtrip_and_nesting(tmp_path):
    path = str(tmp_path / "log" / "run.jsonl")
    rl = RunLog(path)
    rl.event("run.start", rounds=3, arr=np.int64(7))
    with rl.span("outer", tag="a"):
        with rl.span("inner"):
            pass
    rl.counter("queue.wait_s", np.float32(0.25))
    rl.warning("metrics.nonfinite", round=2, keys=["acc"])
    rl.close()

    recs = rl.records()
    # spans record at exit: inner closes before outer
    assert [r["kind"] for r in recs] == ["event", "span", "span",
                                        "counter", "event"]
    inner = next(r for r in recs if r.get("name") == "inner")
    outer = next(r for r in recs if r.get("name") == "outer")
    assert inner["parent"] == outer["id"]       # nesting recorded
    assert outer["parent"] is None
    assert outer["tag"] == "a"
    assert inner["dur"] <= outer["dur"]
    warn = next(r for r in recs if r.get("level") == "warning")
    assert warn["name"] == "metrics.nonfinite" and warn["round"] == 2

    # streaming file == in-memory records == load()
    assert RunLog.load(path) == recs
    # every record is already plain JSON (numpy converted at emit time)
    json.dumps(recs)

    path2 = str(tmp_path / "resaved.jsonl")
    rl.save(path2)
    assert RunLog.load(path2) == recs


def test_runlog_thread_local_nesting():
    """Spans on another thread must not parent under this thread's."""
    import threading
    rl = RunLog()
    got = {}

    def worker():
        with rl.span("worker.span"):
            pass
        got["done"] = True

    with rl.span("main.span"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert got["done"]
    w = next(r for r in rl.records() if r["name"] == "worker.span")
    assert w["parent"] is None


def test_null_runlog_zero_allocation():
    """The disabled path hands back ONE shared span instance — no per-call
    allocation in the hot loop — and records nothing."""
    assert as_runlog(None) is NULL_RUNLOG
    assert isinstance(as_runlog(NULL_RUNLOG), NullRunLog)
    s1 = NULL_RUNLOG.span("chunk.dispatch", r0=0, r1=8)
    s2 = NULL_RUNLOG.span("anything.else")
    assert s1 is s2                      # the shared _NULL_SPAN singleton
    with s1:
        pass
    NULL_RUNLOG.event("e")
    NULL_RUNLOG.counter("c", 1)
    NULL_RUNLOG.warning("w")
    assert NULL_RUNLOG.records() == []
    assert not NULL_RUNLOG.enabled and NULL_RUNLOG.path is None


def test_as_runlog_path(tmp_path):
    p = str(tmp_path / "x.jsonl")
    rl = as_runlog(p)
    assert isinstance(rl, RunLog) and rl.path == p
    rl.event("e")
    rl.close()
    assert RunLog.load(p)[0]["name"] == "e"
    assert as_runlog(rl) is rl


def test_json_safe():
    assert json_safe(np.float32(1.5)) == 1.5
    assert json_safe(np.int64(3)) == 3
    assert json_safe(np.bool_(True)) == 1
    assert json_safe(jnp.arange(3)) == [0, 1, 2]
    assert json_safe(np.float64(2.0)) == 2.0
    assert json_safe({"a": (np.int32(1), None)}) == {"a": [1, None]}
    assert isinstance(json_safe(object()), str)   # fallback, never raises


# ---------------------------------------------------------------------------
# MetricsPump: context-manager lifecycle
# ---------------------------------------------------------------------------

def _comm():
    return CommLog().bind_sizes(
        {"model": {"w": np.zeros(4, np.float32)}})


def test_metrics_pump_clean_exit_drains():
    from repro.engine.metrics import MetricsPump
    comm = _comm()
    with MetricsPump(comm, 2) as pump:
        pump.submit({"local_loss": jnp.asarray([1.0, 2.0])})
    assert comm.rounds == 2
    assert [h["local_loss"] for h in comm.history] == [1.0, 2.0]


def test_metrics_pump_abort_on_exception():
    """Regression: an exception inside the pump context must cancel the
    pending fetches and retire the executor WITHOUT blocking — the old
    close() path would drain (and potentially hang on) device futures
    mid-unwind."""
    from repro.engine.metrics import MetricsPump
    comm = _comm()
    pump = MetricsPump(comm, 2)
    with pytest.raises(RuntimeError, match="boom"):
        with pump:
            pump.submit({"local_loss": jnp.asarray([1.0, 2.0])})
            raise RuntimeError("boom")
    assert not pump._pending                 # queue dropped, not drained
    with pytest.raises(RuntimeError):        # executor is shut down
        pump._pool.submit(lambda: None)


def test_metrics_pump_nonfinite_warning():
    """A NaN/inf metric value still lands in the history untouched (the
    reference-equality contract) but emits a structured warning with its
    round index and key names."""
    from repro.engine.metrics import MetricsPump
    comm = _comm()
    rl = RunLog()
    with MetricsPump(comm, 2, runlog=rl) as pump:
        pump.submit({"local_loss": jnp.asarray([1.0, jnp.nan]),
                     "aux": jnp.asarray([jnp.inf, 2.0])})
    warns = [r for r in rl.records() if r.get("level") == "warning"]
    assert [w["round"] for w in warns] == [1, 2]
    assert warns[0]["keys"] == ["aux"]
    assert warns[1]["keys"] == ["local_loss"]
    assert math.isnan(comm.history[1]["local_loss"])   # value untouched


# ---------------------------------------------------------------------------
# CommLog records + report
# ---------------------------------------------------------------------------

def test_commlog_to_records_save_roundtrip(tmp_path):
    comm = _comm()
    comm.log_round(None, 2, {"acc": np.float32(0.5),
                             "tele/ef_norm": np.float32(0.1)})
    comm.log_round(None, 2, {"acc": np.float32(0.75)})
    recs = comm.to_records()
    json.dumps(recs)                         # plain JSON end to end
    assert [r["kind"] for r in recs] == ["round", "round", "summary"]
    assert recs[0]["acc"] == 0.5 and recs[0]["round"] == 1
    assert recs[-1]["rounds"] == 2
    assert recs[-1]["bytes_up"] == comm.bytes_up

    path = str(tmp_path / "comm.jsonl")
    comm.save(path)
    with open(path) as f:
        loaded = [json.loads(line) for line in f]
    assert loaded == recs


def test_report_from_engine_run(tmp_path):
    """End-to-end: instrumented run -> JSONL artifacts -> report dict
    with the round-time breakdown and telemetry trends."""
    path = str(tmp_path / "run.jsonl")
    res = run_federated(_bundle(), _fl_for("topk"), _data(), rounds=4,
                        seed=1, eval_every=2, superstep_rounds=2,
                        telemetry=True, runlog=path,
                        checkpoint_dir=str(tmp_path / "ck"),
                        checkpoint_every=2)
    assert res.stats["runlog"] == path
    recs = RunLog.load(path)
    report = build_report(recs, res.comm.to_records())
    rt = report["round_time"]
    assert rt["chunks"] == 2 and rt["compiles"] >= 1
    assert rt["dispatch_s"] > 0 and rt["wall_s"] > 0
    assert rt["checkpoint_s"] > 0
    assert "metrics_drain_s" in rt and "prefetch_stall_s" in rt
    assert "eval.dispatch" in report["spans"]
    assert "prefetch.stage" in report["spans"]
    assert report["bytes"]["rounds"] == 4
    assert report["bytes"]["uplink_compression"] > 1   # topk uplink
    assert "tele/ef_norm" in report["telemetry"]
    text = render(report)
    assert "round-time breakdown" in text and "tele/ef_norm" in text


def test_report_ef_page_section_paged_only(tmp_path):
    """A cohort-paged run's report carries the ef_page accounting (rows
    gathered/written back, gather + stall seconds in the round-time
    breakdown); a dense run's report omits the section entirely."""
    reports = {}
    for store in ("host", "device"):
        path = str(tmp_path / f"run_{store}.jsonl")
        res = run_federated(_bundle(), _fl_for("topk"), _data(), rounds=4,
                            seed=1, eval_every=0, superstep_rounds=2,
                            runlog=path, ef_store=store)
        assert res.stats["ef_store"] == store
        reports[store] = build_report(RunLog.load(path),
                                      res.comm.to_records())

    ef = reports["host"]["ef_page"]
    # 2 chunks x 2 rounds x 2 clients, deduped per chunk: every unique
    # gathered row comes back as a writeback row
    rows = ef["hits"] + ef["misses"]
    assert 0 < rows <= 8 and ef["writeback_rows"] == rows
    assert ef["writeback_count"] == 2
    assert ef["gather_count"] == 2 and ef["gather_s"] >= 0
    assert 0 <= ef["hit_rate"] <= 1
    rt = reports["host"]["round_time"]
    assert "ef_gather_s" in rt and "ef_stall_s" in rt
    text = render(reports["host"])
    assert "ef page store" in text and f"written back: {rows} rows" in text

    assert "ef_page" not in reports["device"]
    assert "ef page store" not in render(reports["device"])


def test_report_empty_inputs():
    assert build_report(None, None) == {}
    assert render({}) == "(empty report)"
