"""Participation policies + chaos injection (PR 7 robustness).

Pins, in order of importance:

* chaos-off / ``full_sync`` is BITWISE the pre-participation engine —
  final model, CommLog history and the checkpointed EF state all equal
  the reference loop, per mode x codec (the participation plumbing is
  weight-borne and completely absent from the traced program when off);
* the chaos fault schedule is a pure function of (seed, round index):
  replayable through ``skip_round_sampling``, so interrupt+resume lands
  on the identical schedule and the identical model;
* masked clients' error-feedback residuals are carried forward untouched;
* the masked partial-cohort fused round still runs exactly ONE psum per
  round with chaos + telemetry on (jaxpr-counted on a forced 2-device
  host), and the sharded participation run matches the single-device one;
* policy math (deadline selection, buffered-async staleness discount);
* the robustness satellites: prefetcher shutdown hardening, checkpoint
  save retry, ``halt_on_nonfinite``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import ChaosConfig, FederatedDataset
from repro.data.partition import iid_partition
from repro.data.synth import class_images
from repro.fl.participation import (BufferedAsyncPolicy, DeadlinePolicy,
                                    FullSyncPolicy, ParticipationPolicy,
                                    make_policy, register_policy,
                                    registered_policies)
from repro.fl.server import run_federated, run_federated_reference
from repro.models.registry import make_bundle

_BUNDLE = None


def _bundle():
    global _BUNDLE
    if _BUNDLE is None:
        cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"],
                                  input_shape=(8, 8, 1), conv_channels=(4,),
                                  fc_units=(8,), dropout=0.0)
        _BUNDLE = make_bundle(cfg)
    return _BUNDLE


CHAOS = ChaosConfig(speed_sigma=1.0, jitter=0.2, dropout=0.3,
                    truncation=0.3, seed=7)


def _data(seed=3, chaos=None):
    x, y = class_images(24, n_classes=4, shape=(8, 8, 1), seed=0)
    return FederatedDataset(iid_partition(x, y, 8),
                            {"x": x[:16], "y": y[:16]}, seed=seed,
                            chaos=chaos)


def _fl(**kw):
    kw.setdefault("clients_per_round", 4)
    kw.setdefault("lr", 0.05)
    return FLConfig(algorithm=kw.pop("algorithm", "fedavg"),
                    local_steps=2, local_batch=4, **kw)


def _same_state(a, b):
    for x, y in zip(jax.tree.leaves(a.global_state),
                    jax.tree.leaves(b.global_state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# registry


def test_participation_registry():
    assert set(registered_policies()) >= {"full_sync", "deadline",
                                          "buffered_async"}
    assert isinstance(make_policy("deadline"), DeadlinePolicy)
    with pytest.raises(ValueError, match="unknown participation policy"):
        make_policy("nope")

    class Custom(ParticipationPolicy):
        name = "custom_probe"
        select = FullSyncPolicy.select

    register_policy("custom_probe", Custom)
    assert isinstance(make_policy("custom_probe"), Custom)
    with pytest.raises(ValueError, match="already registered"):
        register_policy("custom_probe", Custom)
    register_policy("custom_probe", Custom, overwrite=True)
    # config validation falls back to the live registry for plugins
    fl = _fl(participation="custom_probe")
    assert fl.participation == "custom_probe"
    with pytest.raises(ValueError, match="unknown participation"):
        _fl(participation="definitely_not_registered")


# ---------------------------------------------------------------------------
# policy math


def test_participation_policy_math():
    fl = _fl(over_provision=1.5, buffer_k=2, staleness_alpha=0.5)
    arrival = np.array([1.0, 4.0, 0.5, 2.0, 8.0, 0.25], np.float32)
    dropped = np.array([False, False, True, False, False, False])

    full = FullSyncPolicy().select(arrival, dropped, fl, 4)
    assert full.round_time == pytest.approx(8.0)   # slowest survivor
    assert full.n_arrived == 5
    assert full.mask.tolist() == [1, 1, 0, 1, 1, 1]
    assert full.weight.tolist() == [1] * 6 and full.staleness.max() == 0

    dl = DeadlinePolicy()
    assert dl.cohort_size(4, fl) == 6
    sel = dl.select(arrival, dropped, fl, 4)
    # 4 fastest ALIVE clients: 0.25, 1.0, 2.0, 4.0 (0.5 is dropped)
    assert sel.mask.tolist() == [1, 1, 0, 1, 0, 1]
    assert sel.round_time == pytest.approx(4.0)
    assert sel.n_arrived == 4

    ba = BufferedAsyncPolicy().select(arrival, dropped, fl, 4)
    # K=2: round closes at the 2nd alive arrival, t=1.0; laggards are
    # staleness-discounted but still contribute
    assert ba.round_time == pytest.approx(1.0)
    assert ba.mask.tolist() == [1, 1, 0, 1, 1, 1]
    s = ba.staleness
    assert s[0] == pytest.approx(0.0) and s[5] == pytest.approx(0.0)
    assert s[1] == pytest.approx(3.0) and s[4] == pytest.approx(7.0)
    np.testing.assert_allclose(ba.weight, (1 + s) ** -0.5, rtol=1e-6)

    # all-dropped guard: the fastest client is un-dropped
    sel = FullSyncPolicy().select(np.array([3.0, 1.0, 2.0], np.float32),
                                  np.array([True, True, True]), fl, 3)
    assert sel.mask.tolist() == [0, 1, 0] and sel.n_arrived == 1


# ---------------------------------------------------------------------------
# chaos layer determinism


def test_chaos_draws_deterministic_and_replayable():
    fl = _fl()
    d1, d2 = _data(chaos=CHAOS), _data(chaos=CHAOS)
    out1 = d1.round_chunk(3, 4, fl.local_steps, fl.local_batch,
                          participation=lambda d: FullSyncPolicy().select(
                              d.arrival, d.dropped, fl, 4))
    out2 = d2.round_chunk(3, 4, fl.local_steps, fl.local_batch,
                          participation=lambda d: FullSyncPolicy().select(
                              d.arrival, d.dropped, fl, 4))
    for a, b in zip(jax.tree.leaves(out1), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    part = out1[3]
    assert part["mask"].shape == (3, 4) and part["round_time"].shape == (3,)
    assert part["n_arrived"].dtype == np.int32

    # skip_round_sampling replays the chaos draws too: a fresh dataset
    # skipped past 2 rounds produces round 3 exactly
    d3 = _data(chaos=CHAOS)
    d3.skip_round_sampling(2, 4, fl.local_steps, fl.local_batch)
    tail = d3.round_chunk(1, 4, fl.local_steps, fl.local_batch,
                          participation=lambda d: FullSyncPolicy().select(
                              d.arrival, d.dropped, fl, 4))
    np.testing.assert_array_equal(tail[0][0], out1[0][2])       # cids
    np.testing.assert_array_equal(tail[3]["mask"][0], part["mask"][2])
    np.testing.assert_array_equal(tail[3]["round_time"][0],
                                  part["round_time"][2])


def test_chaos_stream_independent_of_reader():
    """Chaos draws are consumed iff chaos is configured — never dependent
    on whether a participation callable is reading them — so the batch
    stream is a pure function of (seed, chaos-on?, round)."""
    fl = _fl()
    with_cb = _data(chaos=CHAOS)
    without_cb = _data(chaos=CHAOS)
    a = with_cb.round_chunk(2, 4, fl.local_steps, fl.local_batch,
                            participation=lambda d: FullSyncPolicy().select(
                                d.arrival, d.dropped, fl, 4))
    b = without_cb.round_chunk(2, 4, fl.local_steps, fl.local_batch)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[2], b[2])


def test_chaos_off_consumes_nothing():
    """A chaos-less dataset's rng stream is untouched by the chaos hooks
    — the bitwise-equivalence precondition for every existing run."""
    fl = _fl()
    plain, hooked = _data(), _data()
    a = plain.round_chunk(2, 4, fl.local_steps, fl.local_batch)
    b = hooked.round_chunk(2, 4, fl.local_steps, fl.local_batch,
                           participation=lambda d: FullSyncPolicy().select(
                               np.ones(4, np.float32) if d is None
                               else d.arrival,
                               np.zeros(4, bool) if d is None
                               else d.dropped, fl, 4))
    np.testing.assert_array_equal(a[0], b[0])
    for k in a[1]:
        np.testing.assert_array_equal(a[1][k], b[1][k])
    # and the participation outcome is the trivial all-in round
    assert b[3]["mask"].min() == 1.0 and b[3]["weight"].min() == 1.0


def test_sample_clients_overdraw_raises_participation_hint():
    data = _data()
    with pytest.raises(ValueError, match="over_provision"):
        data.sample_clients(100)


# ---------------------------------------------------------------------------
# engine equivalence pins


@pytest.mark.parametrize("mode", ["client_parallel", "client_sequential"])
@pytest.mark.parametrize("codec", ["identity", "topk"])
def test_chaos_off_full_sync_bitwise(tmp_path, mode, codec):
    """Acceptance: the default config (full_sync, no chaos) is bitwise
    the pre-participation engine — model, CommLog history AND the
    checkpointed EF state equal the reference loop."""
    bundle = _bundle()
    fl = _fl(uplink_codec=codec, topk_frac=0.1, participation="full_sync")
    kw = dict(rounds=4, seed=1, eval_every=2, mode=mode)
    eng = run_federated(bundle, fl, _data(), superstep_rounds=2,
                        checkpoint_dir=str(tmp_path / "eng"), **kw)
    ref = run_federated_reference(bundle, fl, _data(),
                                  checkpoint_dir=str(tmp_path / "ref"),
                                  **kw)
    _same_state(ref, eng)
    assert ref.comm.history == eng.comm.history
    assert ref.comm.bytes_up == eng.comm.bytes_up
    for fname in (("state.npz", "ef.npz") if codec == "topk"
                  else ("state.npz",)):
        a = np.load(tmp_path / "eng" / fname)
        b = np.load(tmp_path / "ref" / fname)
        assert sorted(a.files) == sorted(b.files)
        for k in a.files:
            np.testing.assert_array_equal(a[k], b[k])


@pytest.mark.parametrize("policy", ["deadline", "buffered_async"])
@pytest.mark.parametrize("codec", ["identity", "topk"])
def test_participation_chunk_invariant(policy, codec):
    """Participation runs are superstep-chunk-size invariant, like every
    other engine result (the fault schedule is host-side and the masking
    is weight-borne inside the per-round math)."""
    bundle = _bundle()
    fl = _fl(participation=policy, over_provision=1.5, buffer_k=2,
             uplink_codec=codec, topk_frac=0.1)
    kw = dict(rounds=4, seed=1, eval_every=2)
    r1 = run_federated(bundle, fl, _data(chaos=CHAOS), superstep_rounds=1,
                       **kw)
    r4 = run_federated(bundle, fl, _data(chaos=CHAOS), superstep_rounds=4,
                       **kw)
    _same_state(r1, r4)
    assert r1.comm.history == r4.comm.history
    assert r1.stats["participation"] == policy


def test_chaos_resume_identical_fault_schedule(tmp_path):
    """Interrupt + resume replays the identical fault schedule: the
    resumed run's per-round sim_time/arrived and the final model equal an
    uninterrupted run's."""
    bundle = _bundle()
    fl = _fl(participation="deadline", over_provision=1.5,
             uplink_codec="topk", topk_frac=0.1)
    kw = dict(seed=1, eval_every=2, superstep_rounds=2)
    full = run_federated(bundle, fl, _data(chaos=CHAOS), rounds=6, **kw)
    run_federated(bundle, fl, _data(chaos=CHAOS), rounds=2,
                  checkpoint_dir=str(tmp_path), checkpoint_every=2, **kw)
    resumed = run_federated(bundle, fl, _data(chaos=CHAOS), rounds=6,
                            checkpoint_dir=str(tmp_path),
                            checkpoint_every=2, **kw)
    _same_state(full, resumed)
    tail = [(h["sim_time"], h["arrived"]) for h in full.comm.history][2:]
    assert tail == [(h["sim_time"], h["arrived"])
                    for h in resumed.comm.history]


def test_chaos_partial_uplink_accounting():
    """Dropped clients never upload: bytes_up charges n_arrived clients,
    the downlink still charges the full (over-provisioned) cohort."""
    bundle = _bundle()
    fl = _fl(participation="deadline", over_provision=1.5)
    res = run_federated(bundle, fl, _data(chaos=CHAOS), rounds=4, seed=1,
                        eval_every=2, superstep_rounds=2, telemetry=True)
    assert res.stats["round_cohort"] == 6
    model_b = res.comm._model_b
    for h in res.comm.history:
        assert h["bytes_up"] == int(h["arrived"]) * model_b
        assert h["bytes_down"] == 6 * model_b
        assert h["arrived"] == h["tele/effective_cohort"]
        assert h["tele/dropped_clients"] == 6 - h["arrived"]
        assert h["sim_time"] > 0


def test_chaos_telemetry_staleness_consistency():
    bundle = _bundle()
    fl = _fl(participation="buffered_async", buffer_k=2)
    res = run_federated(bundle, fl, _data(chaos=CHAOS), rounds=4, seed=1,
                        eval_every=2, superstep_rounds=2, telemetry=True)
    assert any(h["tele/mean_staleness"] > 0 for h in res.comm.history)
    assert all(np.isfinite(h["local_loss"]) for h in res.comm.history)


def test_participation_ef_preserved_for_masked_clients():
    """A masked (dropped/late) client's EF residual must come back
    bit-identical — its update never reached the server, so its deferred
    error must not change."""
    from repro.compress import make_codec
    from repro.core.rounds import (init_global_state,
                                   make_compressed_round_fn)
    bundle = _bundle()
    fl = _fl(uplink_codec="topk", topk_frac=0.1)
    uplink = make_codec("topk", topk_frac=0.1)
    downlink = make_codec("identity")
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    uplink.bind(state["model"])
    downlink.bind(state["model"])
    rng = np.random.default_rng(0)
    C, S, B = 4, fl.local_steps, fl.local_batch
    batches = {"x": rng.normal(size=(C, S, B, 8, 8, 1)).astype(np.float32),
               "y": rng.integers(0, 4, size=(C, S, B))}
    ef = jax.tree.map(
        lambda z: rng.normal(size=(C,) + z.shape).astype(np.float32) * 0.1,
        uplink.init_state())
    pmask = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
    round_fn = make_compressed_round_fn(bundle, fl, "client_parallel",
                                        uplink, downlink,
                                        participation=True)
    _, _, new_ef, _ = jax.jit(round_fn)(
        state, {k: np.asarray(v) for k, v in batches.items()},
        np.full((C,), float(B * S), np.float32) * pmask,
        np.float32(0.05), ef, state["model"], jax.random.PRNGKey(1),
        pmask, np.zeros((C,), np.float32))
    for old, new in zip(jax.tree.leaves(ef), jax.tree.leaves(new_ef)):
        old, new = np.asarray(old), np.asarray(new)
        np.testing.assert_array_equal(old[1], new[1])   # masked: untouched
        np.testing.assert_array_equal(old[3], new[3])
        assert not np.array_equal(old[0], new[0])       # active: updated
        assert not np.array_equal(old[2], new[2])


def test_reference_loop_refuses_chaos():
    bundle = _bundle()
    with pytest.raises(NotImplementedError, match="engine feature"):
        run_federated_reference(bundle, _fl(), _data(chaos=CHAOS), rounds=1)
    with pytest.raises(NotImplementedError, match="engine feature"):
        run_federated_reference(bundle, _fl(participation="deadline"),
                                _data(), rounds=1)


# ---------------------------------------------------------------------------
# robustness satellites


def test_prefetcher_surfaces_poisoned_builder():
    from repro.engine.pipeline import HostPrefetcher

    def poisoned(r0, r1):
        if r0 >= 2:
            raise RuntimeError("disk on fire")
        return {"r0": r0}

    # consumed far enough: the exception is raised at the iteration site
    pf = HostPrefetcher(poisoned, [(0, 2), (2, 4)])
    with pytest.raises(RuntimeError, match="disk on fire"):
        list(pf)
    pf.close()

    # consumer stops EARLY: the exception must not die with the worker —
    # close() captures it on .error (and close is idempotent)
    pf = HostPrefetcher(poisoned, [(0, 2), (2, 4), (4, 6)])
    it = iter(pf)
    next(it)
    pf.close()
    pf.close()
    assert isinstance(pf.error, RuntimeError)


def test_checkpoint_save_retries_transient_oserror(tmp_path, monkeypatch):
    from repro.checkpoint.io import save_tree
    from repro.obs.runlog import RunLog

    calls = {"n": 0}
    real_savez = np.savez

    def flaky(path, **kw):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError("flaky fs")
        return real_savez(path, **kw)

    monkeypatch.setattr(np, "savez", flaky)
    monkeypatch.setattr("repro.checkpoint.io._SAVE_BACKOFF_S", 0.001)
    rl = RunLog()
    save_tree(str(tmp_path / "t.npz"), {"a": np.arange(3)}, runlog=rl)
    assert calls["n"] == 3
    retries = [r for r in rl.records()
               if r.get("name") == "checkpoint.save_retries"]
    assert len(retries) == 2
    loaded = np.load(tmp_path / "t.npz")
    np.testing.assert_array_equal(loaded["a"], np.arange(3))

    # persistent failure still raises (bounded retry, not a spin)
    calls["n"] = -10**9
    with pytest.raises(OSError, match="flaky fs"):
        save_tree(str(tmp_path / "t2.npz"), {"a": np.arange(3)})


def test_halt_on_nonfinite_checkpoints_and_stops(tmp_path):
    """A diverging run (lr blown up) halts at the first chunk boundary
    after the non-finite metric instead of training onward on garbage,
    and leaves a resumable checkpoint at the halt boundary."""
    bundle = _bundle()
    fl = _fl(lr=float("inf"))   # inf*grad -> inf-inf -> NaN in round 1
    res = run_federated(bundle, fl, _data(), rounds=8, seed=1,
                        eval_every=4, superstep_rounds=2,
                        checkpoint_dir=str(tmp_path), checkpoint_every=8,
                        halt_on_nonfinite=True)
    assert res.stats["halted_at"] == 2       # NaN in round 1, chunk = 2
    assert len(res.comm.history) == res.stats["halted_at"]
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert meta["round"] == res.stats["halted_at"] and meta["halted"]

    # default: no halt, the run completes (history pins are unaffected)
    res2 = run_federated(bundle, fl, _data(), rounds=4, seed=1,
                         eval_every=4, superstep_rounds=2)
    assert res2.stats["halted_at"] is None
    assert len(res2.comm.history) == 4


# ---------------------------------------------------------------------------
# forced multi-device: sharded participation equivalence + one-psum pin


def _forced_host_env(n_devices):
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src")
    env = dict(os.environ)
    kept = [t for t in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in t]
    env["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={n_devices}"])
    env["REPRO_ALLOW_FORCED_DEVICES"] = "1"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here] + env.get("PYTHONPATH", "").split(os.pathsep))
    return env


_SHARDED_CHAOS_SCRIPT = textwrap.dedent("""
    import jax
    import numpy as np
    assert jax.device_count() == 2, jax.devices()
    from test_participation import CHAOS, _bundle, _data, _fl
    from repro.fl.server import run_federated
    from repro.launch.mesh import make_engine_mesh

    bundle = _bundle()
    mesh = make_engine_mesh()
    kw = dict(rounds=4, seed=1, eval_every=2, superstep_rounds=2)

    # chaos-off full_sync: sharded == sharded (the refactored plumbing is
    # inert), and the participation args never enter the traced program
    fl = _fl(uplink_codec="topk", topk_frac=0.1)
    base = run_federated(bundle, fl, _data(), mesh=mesh, **kw)
    again = run_federated(bundle, fl, _data(), mesh=mesh, **kw)
    for a, b in zip(jax.tree.leaves(base.global_state),
                    jax.tree.leaves(again.global_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert base.comm.history == again.comm.history

    # chaos + deadline: sharded matches single-device (same host fault
    # schedule; aggregation order differs -> allclose), byte-exact comm
    fl = _fl(participation="deadline", over_provision=1.5,
             uplink_codec="topk", topk_frac=0.1)
    single = run_federated(bundle, fl, _data(chaos=CHAOS), **kw)
    sharded = run_federated(bundle, fl, _data(chaos=CHAOS), mesh=mesh,
                            telemetry=True, **kw)
    assert sharded.stats["client_shards"] == 2
    assert sharded.stats["participation"] == "deadline"
    for a, b in zip(jax.tree.leaves(single.global_state),
                    jax.tree.leaves(sharded.global_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    assert single.comm.bytes_up == sharded.comm.bytes_up
    assert single.comm.bytes_down == sharded.comm.bytes_down
    assert [h["sim_time"] for h in single.comm.history] == \\
           [h["sim_time"] for h in sharded.comm.history]
    print("SHARDED-CHAOS-OK")
""")


def test_sharded_participation_forced_2dev():
    env = _forced_host_env(2)
    out = subprocess.run([sys.executable, "-c", _SHARDED_CHAOS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED-CHAOS-OK" in out.stdout


_ONE_PSUM_CHAOS_SCRIPT = textwrap.dedent("""
    import jax
    import jax.numpy as jnp
    assert jax.device_count() == 2, jax.devices()
    from test_participation import _bundle, _fl
    from repro.analysis import count_collectives, round_body
    from repro.compress import make_codec
    from repro.core.rounds import init_global_state
    from repro.engine.sharded import client_sharding, make_sharded_superstep
    from repro.launch.mesh import make_engine_mesh
    from repro.obs.telemetry import make_telemetry

    mesh = make_engine_mesh()
    shard = client_sharding(mesh)
    fl = _fl(participation="deadline", over_provision=1.5,
             uplink_codec="topk", topk_frac=0.1)
    bundle = _bundle()
    uplink = make_codec("topk", topk_frac=0.1)
    downlink = make_codec("identity")
    state = jax.eval_shape(lambda k: init_global_state(bundle, fl, k),
                           jax.random.PRNGKey(0))
    uplink.bind(state["model"])
    downlink.bind(state["model"])
    K, C, S, B = 4, 6, fl.local_steps, fl.local_batch   # cohort C' = 6
    tele = make_telemetry("compressed", n_clients=C,
                          n_shards=shard.n_shards,
                          available=frozenset(("ef", "pmask", "staleness")))
    assert any(t.name == "participation" for t in tele.taps)
    n_loc = 8 // shard.n_shards
    ef = [jax.ShapeDtypeStruct(
              ((n_loc + 1) * shard.n_shards,) + z.shape, z.dtype)
          for z in jax.eval_shape(uplink.init_state)]
    args = (state, ef, state["model"],
            {"x": jax.ShapeDtypeStruct((K, C, S, B, 8, 8, 1), jnp.float32),
             "y": jax.ShapeDtypeStruct((K, C, S, B), jnp.int32)},
            jax.ShapeDtypeStruct((K, C), jnp.float32),
            jax.ShapeDtypeStruct((K,), jnp.float32),
            jax.ShapeDtypeStruct((K, C), jnp.int32),
            jax.ShapeDtypeStruct((K,), jnp.int32),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
            jax.ShapeDtypeStruct((K, C), jnp.float32),   # pmask
            jax.ShapeDtypeStruct((K, C), jnp.float32))   # pstale
    fn = make_sharded_superstep(bundle, fl, "client_parallel", K, mesh,
                                uplink=uplink, downlink=downlink,
                                fused_collective=True, telemetry=tele,
                                participation=True)
    jaxpr = jax.make_jaxpr(fn)(*args)
    body = round_body(jaxpr)
    per_round = count_collectives(body)
    total = count_collectives(jaxpr)
    assert per_round == 1, f"masked fused round has {per_round} psums"
    assert total == 2, f"superstep has {total} psums"
    print("CHAOS-ONE-PSUM-OK")
""")


def test_masked_fused_round_still_one_psum():
    """Acceptance: the partial-cohort round with chaos masking, staleness
    weights AND telemetry on still executes exactly ONE psum per round —
    masking is weight-borne and the masked-loss/tap lanes ride the
    existing collective."""
    env = _forced_host_env(2)
    out = subprocess.run([sys.executable, "-c", _ONE_PSUM_CHAOS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "CHAOS-ONE-PSUM-OK" in out.stdout
