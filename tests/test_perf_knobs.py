"""Perf knobs must not change semantics: remat == same loss & gradients."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_CONFIGS
from repro.models import transformer as tfm


def _loss_fn(cfg):
    def loss(params, batch, labels):
        out = tfm.forward_seq(cfg, params, batch)
        lg = out["logits"].astype(jnp.float32)
        lz = jax.nn.logsumexp(lg, -1)
        oh = jax.nn.one_hot(labels, lg.shape[-1])
        return jnp.mean(lz - jnp.sum(lg * oh, -1))
    return loss


@pytest.mark.parametrize("arch", ["smollm-135m", "granite-moe-1b-a400m",
                                  "gemma3-1b"])
@pytest.mark.parametrize("remat", ["attn", "layer"])
def test_remat_preserves_loss_and_grads(arch, remat):
    base = ARCH_CONFIGS[arch].reduced()
    cfg_r = dataclasses.replace(base, remat=remat)
    params = tfm.init_params(base, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          base.vocab_size)}
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                base.vocab_size)

    l0, g0 = jax.value_and_grad(_loss_fn(base))(params, batch, labels)
    l1, g1 = jax.value_and_grad(_loss_fn(cfg_r))(params, batch, labels)
    np.testing.assert_allclose(l0, l1, rtol=1e-6)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4),
        g0, g1)


def test_serve_ep_flag_accepted_on_host_mesh():
    """EP sharding rules produce valid specs on any mesh (host mesh here;
    the 256-chip layout is proven by the dry-run artifacts)."""
    from repro.launch import sharding as sh
    from repro.launch.mesh import make_host_mesh
    cfg = ARCH_CONFIGS["granite-moe-1b-a400m"].reduced()
    struct = jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    shardings = sh.param_shardings(mesh, struct, fsdp=False, ep=True)
    jax.tree.map(lambda leaf, s: None, struct, shardings)  # structure match


def test_pallas_attn_impl_matches_jnp_end_to_end():
    """attn_impl='pallas' (flash train kernel, interpret mode on CPU) gives
    the same loss and gradients as the jnp scan path inside a full model."""
    base = ARCH_CONFIGS["smollm-135m"].reduced()
    cfg_p = dataclasses.replace(base, attn_impl="pallas")
    params = tfm.init_params(base, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          base.vocab_size)}
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                base.vocab_size)
    l0, g0 = jax.value_and_grad(_loss_fn(base))(params, batch, labels)
    l1, g1 = jax.value_and_grad(_loss_fn(cfg_p))(params, batch, labels)
    np.testing.assert_allclose(l0, l1, rtol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, atol=5e-5, rtol=1e-3),
        g0, g1)
