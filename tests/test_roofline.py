"""Structural HLO analyzer: loop-aware FLOPs / bytes / collective parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import analyze_entry, parse_computations


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_counted():
    a = jnp.zeros((128, 256))
    b = jnp.zeros((256, 64))
    text = _compiled_text(lambda x, y: x @ y, a, b)
    cost = analyze_entry(text)
    want = 2 * 128 * 256 * 64
    assert want * 0.99 <= cost.flops <= want * 1.5  # layout noise tolerated


def test_scan_multiplies_by_trip_count():
    """The whole point of the custom analyzer: a scanned body counts
    trip_count times, not once (XLA cost_analysis counts it once)."""
    w = jnp.zeros((64, 64))

    def one(x):
        return x @ w

    def scanned(x):
        def body(h, _):
            return one(h), None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    t1 = _compiled_text(one, jnp.zeros((8, 64)))
    t10 = _compiled_text(scanned, jnp.zeros((8, 64)))
    c1 = analyze_entry(t1)
    c10 = analyze_entry(t10)
    assert c10.flops >= 9 * c1.flops, (c1.flops, c10.flops)
    assert c10.flops <= 12 * c1.flops


def test_nested_scan_multiplies():
    w = jnp.zeros((32, 32))

    def nested(x):
        def outer(h, _):
            def inner(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, None, length=4)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    cost = analyze_entry(_compiled_text(nested, jnp.zeros((8, 32))))
    want = 12 * 2 * 8 * 32 * 32
    assert want * 0.9 <= cost.flops <= want * 1.6


def test_bytes_positive_for_memory_bound_op():
    x = jnp.zeros((1024, 1024))
    cost = analyze_entry(_compiled_text(lambda a: a.T + 1.0, x))
    assert cost.bytes >= 2 * 1024 * 1024 * 4  # read + write at least


def test_no_collectives_on_single_device():
    x = jnp.zeros((64, 64))
    cost = analyze_entry(_compiled_text(lambda a: a @ a, x))
    assert cost.total_coll_bytes == 0


def test_parse_finds_entry():
    text = _compiled_text(lambda a: a * 2, jnp.zeros(4))
    comps, entry = parse_computations(text)
    assert entry in comps
    assert len(comps[entry].ops) >= 1


def test_collective_parsing_from_synthetic_hlo():
    """Hand-written HLO snippet with an all-reduce: payload counted once."""
    text = """
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256] parameter(0)
  ROOT %ar = f32[128,256] all-reduce(%p0), to_apply=%add
}
"""
    cost = analyze_entry(text)
    assert cost.coll_bytes["all-reduce"] == 128 * 256 * 4
    assert cost.coll_counts["all-reduce"] == 1
