"""Per-architecture smoke tests (deliverable f).

For each assigned architecture: instantiate the REDUCED variant (2 layers,
d_model<=512, <=4 experts), run one forward pass and one FL-round train step
on CPU, asserting output shapes and absence of NaNs; plus one decode step
against a prefill-built cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_CONFIGS
from repro.configs.base import FLConfig
from repro.core.rounds import init_global_state, make_round_fn
from repro.models import transformer as tfm
from repro.models.registry import make_bundle

ARCHS = sorted(ARCH_CONFIGS)
B, S = 2, 16


def _batch(cfg, key, b=B, s=S):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            ks[1], (b, cfg.n_vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["audio_frames"] = jax.random.normal(
            ks[2], (b, cfg.n_audio_frames, cfg.d_model))
    return batch


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = ARCH_CONFIGS[name].reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    out = jax.jit(lambda p, b: tfm.forward_seq(cfg, p, b))(params, batch)
    assert out["logits"].shape == (B, S, cfg.vocab_size)
    assert out["features"].shape == (B, S, cfg.d_model)
    assert _finite(out)


@pytest.mark.parametrize("name", ARCHS)
@pytest.mark.parametrize("algorithm", ["fedavg", "fedfusion"])
def test_train_round_step(name, algorithm):
    """One full FL round (the system's train step) on the reduced config."""
    cfg = ARCH_CONFIGS[name].reduced()
    bundle = make_bundle(cfg)
    fl = FLConfig(algorithm=algorithm, fusion_op="conv", local_steps=2,
                  lr=1e-3)
    round_fn = jax.jit(make_round_fn(bundle, fl, "client_parallel"))
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))

    n_clients, steps = 2, 2
    key = jax.random.PRNGKey(2)
    sub = jax.random.split(key, n_clients * steps)
    per = [_batch(cfg, sub[i]) for i in range(n_clients * steps)]
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape((n_clients, steps) + xs[0].shape),
        *per)
    # independent random labels — labels==tokens is trivially predictable
    # through the tied-embedding residual stream (loss ~ 0, no gradient)
    batches["labels"] = jax.random.randint(
        jax.random.PRNGKey(9), batches["tokens"].shape, 0, cfg.vocab_size)

    new_state, metrics = round_fn(state, batches,
                                  jnp.ones(n_clients), jnp.float32(1e-3))
    assert _finite(new_state)
    assert np.isfinite(float(metrics["local_loss"]))
    # parameters actually moved
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                        state["model"], new_state["model"])
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step(name):
    cfg = ARCH_CONFIGS[name].reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    max_len = 32
    cache = tfm.init_cache(cfg, B, max_len)
    tok = jnp.array([[1], [2]], jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, t, c: tfm.decode_step(cfg, p, t, c, jnp.int32(0)))(
            params, tok, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert _finite(logits)
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_then_decode_matches_forward(name):
    """forward(S+1 tokens).logits[:, -1] == decode(token S | prefill cache).

    This is the serving-correctness invariant: the cache built by prefill
    plus one decode step must reproduce the full-sequence forward.

    MoE archs run with capacity covering all tokens: capacity *drops* depend
    on the total token count T, so the S- and (S+1)-token forwards would
    legitimately diverge under a tight factor (tested in test_models).
    """
    import dataclasses
    cfg = ARCH_CONFIGS[name].reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity=float(cfg.n_experts))
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    s_total = 12
    batch = _batch(cfg, key, b=B, s=s_total)

    full = tfm.forward_seq(cfg, params, batch)

    pre_batch = {k: (v[:, : s_total - 1] if k == "tokens" else v)
                 for k, v in batch.items()}
    pre = tfm.forward_seq(cfg, params, pre_batch, want_cache=True,
                          max_cache_len=s_total)
    logits, _ = tfm.decode_step(cfg, params, batch["tokens"][:, -1:],
                                pre["cache"], jnp.int32(s_total - 1))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full["logits"][:, -1]),
                               atol=2e-3, rtol=2e-3)
