"""End-to-end system tests: federated training actually learns.

Miniature versions of the paper's experiments — tiny CNN, synthetic
class-structured images, a few rounds — asserting the system-level
behaviours the paper claims (learning happens; two-stream mechanisms
don't break convergence; comm accounting tracks rounds).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_CONFIGS, CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import artificial_noniid_partition, iid_partition
from repro.data.synth import class_images, token_stream
from repro.data.partition import source_partition
from repro.fl.newclient import newclient_convergence
from repro.fl.server import evaluate, run_federated
from repro.models.registry import make_bundle


def _tiny_cnn_bundle():
    cfg = dataclasses.replace(
        CNN_CONFIGS["cnn_mnist"], input_shape=(12, 12, 1),
        conv_channels=(8, 16), fc_units=(32,), dropout=0.0)
    return make_bundle(cfg)


def _fed_data(partition, n_clients=8, n_per_class=40, seed=0):
    x, y = class_images(n_per_class, n_classes=10, shape=(12, 12, 1),
                        seed=seed, noise=0.2)
    xt, yt = class_images(10, n_classes=10, shape=(12, 12, 1),
                          seed=seed, noise=0.2)
    return FederatedDataset(partition(x, y, n_clients),
                            {"x": xt, "y": yt}, seed=seed)


@pytest.mark.parametrize("algorithm", ["fedavg", "fedmmd", "fedfusion"])
def test_federated_cnn_learns_iid(algorithm):
    bundle = _tiny_cnn_bundle()
    fl = FLConfig(algorithm=algorithm, fusion_op="multi", clients_per_round=4,
                  local_steps=6, local_batch=16, lr=0.1, mmd_lambda=0.1)
    data = _fed_data(iid_partition)
    res = run_federated(bundle, fl, data, rounds=15, eval_every=15)
    final = res.comm.history[-1]
    assert final["acc"] > 0.6, final
    assert res.comm.rounds == 15


def test_federated_cnn_learns_noniid_fedavg_baseline():
    bundle = _tiny_cnn_bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=4, local_steps=6,
                  local_batch=16, lr=0.1)
    data = _fed_data(lambda x, y, n: artificial_noniid_partition(
        x, y, n, shards_per_client=2))
    res = run_federated(bundle, fl, data, rounds=20, eval_every=20)
    assert res.comm.history[-1]["acc"] > 0.4


def test_fedmmd_matches_or_beats_fedavg_loss_trajectory():
    """Same seeds and client sampling: FedMMD's extra constraint must not
    blow up training (paper: same convergence point, faster en route)."""
    data_args = dict(n_clients=6, n_per_class=30, seed=3)
    accs = {}
    for algo in ("fedavg", "fedmmd"):
        bundle = _tiny_cnn_bundle()
        fl = FLConfig(algorithm=algo, clients_per_round=3, local_steps=3,
                      local_batch=16, lr=0.05, mmd_lambda=0.1)
        data = _fed_data(lambda x, y, n: artificial_noniid_partition(
            x, y, n, shards_per_client=2), **data_args)
        res = run_federated(bundle, fl, data, rounds=10, eval_every=10,
                            seed=7)
        accs[algo] = res.comm.history[-1]["acc"]
    assert accs["fedmmd"] > accs["fedavg"] - 0.15, accs


def test_fedfusion_deployed_model_evaluates():
    """After training, the deployed global model (self-fused) is usable."""
    bundle = _tiny_cnn_bundle()
    fl = FLConfig(algorithm="fedfusion", fusion_op="conv",
                  clients_per_round=4, local_steps=6, local_batch=16, lr=0.1)
    data = _fed_data(iid_partition)
    res = run_federated(bundle, fl, data, rounds=10)
    m = evaluate(bundle, fl, res.global_state, data.test_batch())
    assert m["acc"] > 0.3
    assert np.isfinite(m["loss"])


def test_newclient_probe_improves_over_epochs():
    bundle = _tiny_cnn_bundle()
    fl = FLConfig(algorithm="fedfusion", fusion_op="conv",
                  clients_per_round=4, local_steps=3, local_batch=16, lr=0.05)
    data = _fed_data(iid_partition)
    res = run_federated(bundle, fl, data, rounds=5)
    x, y = class_images(20, n_classes=10, shape=(12, 12, 1), seed=99,
                        noise=0.25, template_seed=0)
    accs = newclient_convergence(bundle, fl, res.global_state,
                                 {"x": x, "y": y}, epochs=4, batch=16, lr=0.05)
    assert len(accs) == 4
    assert accs[-1] >= accs[0] - 0.05  # local adaptation does not regress


def test_comm_accounting_scales_with_clients():
    bundle = _tiny_cnn_bundle()
    data = _fed_data(iid_partition)
    logs = {}
    for cpr in (2, 4):
        fl = FLConfig(algorithm="fedavg", clients_per_round=cpr,
                      local_steps=2, local_batch=8, lr=0.05)
        res = run_federated(bundle, fl, data, rounds=3)
        logs[cpr] = res.comm
    assert logs[4].bytes_up == 2 * logs[2].bytes_up


def test_federated_lm_round_reduces_loss():
    """The same FL core drives the LM architectures: a few rounds of
    client-parallel FedAvg on the bigram synthetic stream reduce test loss."""
    cfg = dataclasses.replace(ARCH_CONFIGS["smollm-135m"].reduced(),
                              vocab_size=64)
    bundle = make_bundle(cfg)
    toks, src = token_stream(120, 16, vocab=64, n_sources=4, seed=0)
    ds = FederatedDataset(source_partition(toks, src, 4),
                          {"tokens": toks[:32]})
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=2,
                  local_batch=8, lr=0.1)
    res = run_federated(bundle, fl, ds, rounds=6, eval_every=3,
                        eval_examples=32)
    losses = [h["loss"] for h in res.comm.history if "loss" in h]
    assert losses[-1] < losses[0], losses


def test_fedfusion_lm_round_runs():
    cfg = dataclasses.replace(ARCH_CONFIGS["smollm-135m"].reduced(),
                              vocab_size=64)
    bundle = make_bundle(cfg)
    toks, src = token_stream(60, 16, vocab=64, n_sources=4, seed=0)
    ds = FederatedDataset(source_partition(toks, src, 4),
                          {"tokens": toks[:16]})
    fl = FLConfig(algorithm="fedfusion", fusion_op="multi",
                  clients_per_round=2, local_steps=2, local_batch=4, lr=0.05)
    res = run_federated(bundle, fl, ds, rounds=2, eval_every=2,
                        eval_examples=16)
    assert np.isfinite(res.comm.history[-1]["loss"])
    assert "fusion" in res.global_state
